"""Quickstart: build an assigned architecture at smoke scale, take one
training step, then prefill + decode — the whole public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py [arch]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, reduced
from repro.configs.base import RunConfig, ShapeConfig, TrainConfig
from repro.models import build
from repro.train.step import init_train_state, make_train_step

arch = sys.argv[1] if len(sys.argv) > 1 else "phi3-mini-3.8b"
cfg = reduced(ALL_ARCHS[arch])          # same family, laptop-sized
model = build(cfg)
key = jax.random.PRNGKey(0)

# --- one training step ---
shape = ShapeConfig("demo", "train", 64, 2)
run = RunConfig(model=cfg, shape=shape, train=TrainConfig(remat="full"))
state = init_train_state(model, key)
step = jax.jit(make_train_step(model, run))
batch = model.sample_batch(shape, key)
state, metrics = step(state, batch)
print(f"[train]  arch={cfg.name}  loss={float(metrics['loss']):.4f}  "
      f"grad_norm={float(metrics['grad_norm']):.3f}")

# --- prefill + a few greedy decode steps ---
prompt = model.sample_batch(ShapeConfig("p", "prefill", 16, 2), key)
logits, cache = jax.jit(
    lambda p, b: model.prefill(p, b, cache_len=32))(state.params, prompt)
decode = jax.jit(model.decode_step)
pos = jnp.full((2,), 16, jnp.int32)
toks = []
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
for _ in range(8):
    logits, cache = decode(state.params, cache, tok, pos)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    toks.append(int(tok[0, 0]))
    pos = pos + 1
print(f"[decode] greedy continuation: {toks}")
print("OK")
