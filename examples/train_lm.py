"""End-to-end driver: train a ~20M-parameter dense LM for a few hundred
steps with the full production substrate — data pipeline, AdamW, remat,
checkpointing, manifest attestation, straggler tracking.

    PYTHONPATH=src python examples/train_lm.py --steps 200

(Scale note: this container is one CPU core; the 20M config keeps a few
hundred steps in the tens of minutes.  On a real pod the same driver with
``--production-mesh --full`` trains the assigned full configs.)
"""
import argparse
import dataclasses
import json

from repro.configs import ALL_ARCHS
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--out", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~20M-parameter member of the phi3 (dense) family
    import repro.core.registry as registry
    base = ALL_ARCHS["phi3-mini-3.8b"]
    small = dataclasses.replace(
        base, name="phi3-20m", n_layers=6, d_model=384, n_heads=6,
        n_kv_heads=6, head_dim=64, d_ff=1024, vocab_size=8192)
    registry.ALL_ARCHS = dict(ALL_ARCHS)  # leave the global registry alone

    # route through the launcher by monkey-free direct call:
    from repro.launch import train as T

    orig = T.resolve_arch
    T.resolve_arch = lambda name: small if name == "phi3-20m" else orig(name)
    try:
        res = train("phi3-20m", smoke=False, steps=args.steps,
                    seq_len=64, global_batch=4, ckpt_every=max(args.steps // 4, 1),
                    out_dir=args.out)
    finally:
        T.resolve_arch = orig
    print(json.dumps(res, indent=1, default=str))
    assert res["loss_decreased"], "training did not reduce the loss"


if __name__ == "__main__":
    main()
