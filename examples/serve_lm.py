"""Serve a small model through the unified request-lifecycle API: submit
requests with per-request SamplingParams, stream one request's tokens as
they decode, cancel another mid-flight, and drain the rest — all on the
paged continuous-batching engine (prefix cache, chunked prefill).

    PYTHONPATH=src python examples/serve_lm.py
"""
import json

import jax
import numpy as np

from repro.configs import ALL_ARCHS, reduced
from repro.launch.serve import serve
from repro.models import build
from repro.serve import PagedServeEngine, Request, SamplingParams

if __name__ == "__main__":
    # the one-call driver (submit + drain under the hood)
    res = serve("deepseek-7b", n_requests=8, slots=4, max_len=96, max_new=12,
                shared_prefix=24)
    print(json.dumps(res, indent=1))
    assert res["served"] == 8
    assert res["engine"] == "paged" and res["cached_tokens"] > 0

    # the lifecycle API directly: streaming, sampling, cancellation
    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = PagedServeEngine(model, params, slots=2, max_len=64,
                           block_size=8, chunk=4)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=12).tolist()
    sampled = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, seed=42)

    stream = eng.submit(Request(rid=0, prompt=prompt, max_new=8,
                                sampling=sampled))
    doomed = eng.submit(Request(rid=1, prompt=list(prompt), max_new=8))
    tokens = []
    for tok in stream:           # pulls engine.step() as needed
        tokens.append(tok)
        if len(tokens) == 2:
            doomed.cancel()      # mid-flight: pages released immediately
    print("streamed:", tokens)
    assert len(tokens) == 8 and stream.finished
    assert doomed.cancelled and not doomed.finished
    eng.alloc.check()

    # counter-based sampling replays exactly: same (seed, rid) => same stream
    eng2 = PagedServeEngine(model, params, slots=2, max_len=64,
                            block_size=8, chunk=4)
    replay = eng2.submit(Request(rid=0, prompt=list(prompt), max_new=8,
                                 sampling=sampled)).result()
    assert replay.out == tokens, (replay.out, tokens)
    print("OK")
