"""Serve a small model with batched requests through the paged
continuous-batching engine (prefix cache, chunked prefill, TTFT,
occupancy).  The shared prompt prefix makes the page reuse visible.

    PYTHONPATH=src python examples/serve_lm.py
"""
import json

from repro.launch.serve import serve

if __name__ == "__main__":
    res = serve("deepseek-7b", n_requests=8, slots=4, max_len=96, max_new=12,
                shared_prefix=24)
    print(json.dumps(res, indent=1))
    assert res["served"] == 8
    assert res["engine"] == "paged" and res["cached_tokens"] > 0
    print("OK")
