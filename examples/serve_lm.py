"""Serve a small model with batched requests through the continuous-
batching engine (slots, TTFT, occupancy).

    PYTHONPATH=src python examples/serve_lm.py
"""
import json

from repro.launch.serve import serve

if __name__ == "__main__":
    res = serve("deepseek-7b", n_requests=8, slots=4, max_len=96, max_new=12)
    print(json.dumps(res, indent=1))
    assert res["served"] == 8
    print("OK")
