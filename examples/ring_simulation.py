"""The paper's application workload: a Hodgkin–Huxley ring network
(Arbor ring benchmark) — watch the action potential propagate one cell per
axonal-delay epoch, then compare the jnp path against the Pallas HH-kernel
path (the dual-environment check on real physiology).

    PYTHONPATH=src python examples/ring_simulation.py
"""
import numpy as np

from repro.neuro.cable import CellConfig
from repro.neuro.ring import RingConfig
from repro.neuro.sim import simulate

cfg = RingConfig(n_cells=48, t_end_ms=45.0,
                 cell=CellConfig(n_compartments=8))
r = simulate(cfg)
front = np.asarray(r.wavefront)
print(f"cells={cfg.n_cells}  epochs={cfg.n_epochs}  "
      f"delay={cfg.delay_ms}ms  dt={cfg.cell.dt}ms")
print(f"total spikes: {r.total_spikes}")
print("wavefront per epoch:", front.tolist())
reached = front[front >= 0]  # -1 = no spike that epoch (EPSP rise time can
# push the last hop past t_end — the wave continues, the clock stops)
assert (np.diff(reached) >= 0).all(), "wave must advance monotonically"
assert r.total_spikes == int(reached[-1]) + 1

rk = simulate(cfg, use_pallas=True)
assert np.array_equal(np.asarray(r.spike_counts),
                      np.asarray(rk.spike_counts)), "kernel parity"
print("pallas HH kernel path: spike-for-spike identical")
print("OK")
