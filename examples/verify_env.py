"""The paper's core loop, end to end: capture a portable environment
manifest, bind it to this host, lower a train step, inspect the compiled
collectives for pathway misconfigurations, and run a dual-environment
numeric check — the automated version of the paper's Table 1 + §8.

    PYTHONPATH=src python examples/verify_env.py
"""
import jax
import numpy as np

from repro.configs import ALL_ARCHS, SHAPES, TINY_MESH, reduced
from repro.configs.base import RunConfig, ShapeConfig, TrainConfig
from repro.core import (Diagnostics, DualEnvHarness, Manifest, PortableEnv,
                        parse_hlo)
from repro.launch import bind as B
from repro.launch.mesh import make_mesh
from repro.models import build
from repro.parallel import bind as ctx_bind, rules_for
from repro.train.step import abstract_train_state, make_train_step

cfg = reduced(ALL_ARCHS["deepseek-7b"])
shape = ShapeConfig("demo", "train", 64, 2)
tc = TrainConfig(remat="full")
run = RunConfig(model=cfg, shape=shape, train=tc)
mesh = make_mesh(TINY_MESH)
model = build(cfg)

# 1. the portable part (the "image"): content-addressed
manifest = Manifest(PortableEnv.capture(cfg, shape, tc, run.rules))
print(f"image hash            : {manifest.portable.image_hash}")

# 2. the host binding (the "--nv / --mpi=pmix" moment)
manifest.bind(mesh)
print(f"host binding          : {manifest.binding.device_kind} "
      f"x{manifest.binding.n_devices}, mesh {manifest.binding.mesh_shape}")

# 3. lower + attest: HLO fingerprint + collective pathways
with ctx_bind(mesh, rules_for(run)):
    step = make_train_step(model, run)
    st_sh = B.state_shardings(model, mesh)
    b_sh = B.batch_shardings(model, shape, mesh)
    compiled = jax.jit(step, in_shardings=(st_sh, b_sh),
                       out_shardings=(st_sh, None), donate_argnums=(0,)
                       ).lower(abstract_train_state(model),
                               model.input_specs(shape)).compile()
report = parse_hlo(compiled.as_text(), mesh.devices.size)
manifest.attest(hlo_text=compiled.as_text(), collectives=report.summary())
print(f"hlo fingerprint       : {manifest.attestation['hlo_fingerprint']}")
print(f"collectives           : {report.counts() or 'none (single device)'}")

# 4. diagnostics gate (the paper's §8 automated log review)
diag = Diagnostics()
diag.extend(report.findings, "train-step")
print(diag.render())

# 5. dual-environment numeric verification (native == container)
params = model.init_params(jax.random.PRNGKey(0))
batch = model.sample_batch(shape, jax.random.PRNGKey(1))
h = DualEnvHarness(repeats=2, warmup=1)
rep = h.compare(
    "eager", lambda: np.asarray(model.loss(params, batch)[0], np.float32),
    "jit", lambda: np.asarray(
        jax.jit(lambda p, b: model.loss(p, b)[0])(params, batch), np.float32),
    rtol=1e-2)
print(f"dual-env verdicts     : "
      f"{[(v.kind, v.ok, v.detail) for v in rep.verdicts]}")
assert rep.ok and diag.gate()
print("OK — environment is performance-verified")
