"""Mesh construction.  Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


# canonical version-compat helper lives in the layering-neutral
# parallel.ctx; re-exported here where mesh construction is expected
from repro.parallel.ctx import mesh_of  # noqa: F401


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return mesh_of(shape, axes)


def make_mesh(cfg: MeshConfig):
    return mesh_of(cfg.shape, cfg.axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    from repro.configs.base import MULTI_POD, SINGLE_POD

    return MULTI_POD if multi_pod else SINGLE_POD
