"""Late binding of a portable (model, shape, rules) description onto a
physical mesh: NamedSharding trees for state, batches and caches.

This module is the TPU analogue of the paper's PMIx wire-up — the image
(model code + config) is host-agnostic; ``bind_*`` attaches the
site-specific topology at launch time.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs.base import RunConfig, ShapeConfig
from repro.models import params as P
from repro.models.model import Model
from repro.optim.adamw import OptState
from repro.parallel import ctx as shardctx
from repro.train.step import TrainState

_BATCH_AXES: dict[str, tuple[str | None, ...]] = {
    "tokens": ("act_batch", "act_seq"),
    "labels": ("act_batch", "act_seq"),
    "token": ("act_batch", None),
    "pos": ("act_batch",),
    "image_embed": ("act_batch", None, None),
    "audio_embed": ("act_batch", "act_seq", None),
}


def batch_shardings(model: Model, shape: ShapeConfig, mesh) -> dict[str, Any]:
    specs = model.input_specs(shape)
    out = {}
    for name, sds in specs.items():
        logical = _BATCH_AXES[name]
        out[name] = NamedSharding(mesh, shardctx.resolve(logical, sds.shape))
    return out


def param_shardings(model: Model, mesh):
    return P.shardings(model.param_specs(), mesh)


def state_shardings(model: Model, mesh) -> TrainState:
    ps = param_shardings(model, mesh)
    return TrainState(
        params=ps,
        opt=OptState(step=NamedSharding(mesh, PS()), master=ps, m=ps, v=ps),
    )


def cache_shardings(model: Model, mesh, batch: int, seq_len: int):
    return P.shardings(model.cache_specs(batch, seq_len), mesh)


def _logits_sharding(model: Model, mesh, batch: int):
    spec = shardctx.resolve(("act_batch", "act_vocab"),
                            (batch, model.cfg.padded_vocab))
    return NamedSharding(mesh, spec)


def abstract_cell(model: Model, run: RunConfig, mesh):
    """(fn, abstract_args, in_shardings, out_shardings, donate) for one
    assignment cell — ready for jax.jit(...).lower(...).  Explicit
    out_shardings pin the state/cache layouts so donation aliases cleanly
    and XLA cannot decide to materialize replicated state."""
    from repro.train.step import abstract_train_state, make_train_step

    shape = run.shape
    if shape.kind == "train":
        step = make_train_step(model, run)
        args = (abstract_train_state(model), model.input_specs(shape))
        st_sh = state_shardings(model, mesh)
        shards = (st_sh, batch_shardings(model, shape, mesh))
        return step, args, shards, (st_sh, None), (0,)
    if shape.kind == "prefill":
        fn = lambda params, batch: model.prefill(params, batch)
        args = (model.abstract_params(), model.input_specs(shape))
        shards = (param_shardings(model, mesh),
                  batch_shardings(model, shape, mesh))
        prompt = (model.cfg.decoder_train_len
                  if model.cfg.family == "encdec" else shape.seq_len)
        out = (_logits_sharding(model, mesh, shape.global_batch),
               cache_shardings(model, mesh, shape.global_batch, prompt))
        return fn, args, shards, out, ()
    # decode
    fn = model.decode_step
    inputs = model.input_specs(shape)
    cache = model.abstract_cache(shape.global_batch, shape.seq_len)
    args = (model.abstract_params(), cache, inputs["token"], inputs["pos"])
    bsh = batch_shardings(model, shape, mesh)
    c_sh = cache_shardings(model, mesh, shape.global_batch, shape.seq_len)
    shards = (param_shardings(model, mesh), c_sh, bsh["token"], bsh["pos"])
    out = (_logits_sharding(model, mesh, shape.global_batch), c_sh)
    return fn, args, shards, out, (1,)
