import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend init.  This module is the only place the 512
# placeholder devices exist — tests and benches see the real single device.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.audit.trace import Tracer
from repro.configs import ALL_ARCHS, SHAPES, applicable_shapes
from repro.configs.base import RunConfig, TrainConfig
from repro.core.inspector import hlo_cost, parse_hlo
from repro.launch.bind import abstract_cell
from repro.launch.mesh import make_production_mesh, mesh_config
from repro.models import build
from repro.models.stack import nonembedding_param_count, param_count
from repro.parallel import bind as ctx_bind, rules_for


HBM_BYTES = 16 * 2**30  # TPU v5e


def _default_microbatches(cfg, shape) -> int:
    """Pick gradient-accumulation depth so the per-device saved-activation
    stack (≈ L·D·tokens_dev·2B ×2.9 measured slope, see EXPERIMENTS §Dry-run)
    targets <12 GiB.  Powers of two only."""
    if not shape.is_train:
        return 0
    est_gib = 7.4 * (cfg.n_layers * cfg.d_model) / 98304.0
    mb = 1
    while est_gib / mb > 11.0 and mb < 16:
        mb *= 2
    return mb if mb > 1 else 0


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                rules: str = "auto", remat: str = "full",
                microbatches: int | None = None,
                out_dir: str | None = None, verbose: bool = True,
                tracer: Tracer | None = None) -> dict:
    """Lower + compile one (arch × shape × mesh) cell; return the record.
    The cell's trace (lower/compile spans, error events) is dumped into
    the artifact so a failed or slow sweep can be audited offline."""
    cfg = ALL_ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mb = (_default_microbatches(cfg, shape)
          if microbatches is None else microbatches)
    run = RunConfig(model=cfg, shape=shape, mesh=mesh_config(multi_pod=multi_pod),
                    rules=rules, train=TrainConfig(remat=remat, microbatches=mb))
    model = build(cfg)
    n_dev = mesh.devices.size
    trace = tracer or Tracer(capacity=256)
    trace_start = trace.emitted      # dump only this cell's events below

    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "axes": list(mesh.axis_names),
        "rules": run.rules, "remat": remat, "microbatches": mb,
        "params": param_count(cfg),
        "params_active": param_count(cfg, active_only=True),
        "params_nonembed_active": nonembedding_param_count(cfg, True),
        "status": "ok",
    }
    t0 = time.time()
    try:
        with ctx_bind(mesh, rules_for(run)):
            fn, args, shards, out_shards, donate = abstract_cell(model, run, mesh)
            with trace.span("dryrun-lower", arch=arch, shape=shape_name,
                            mesh=rec["mesh"], rules=run.rules):
                lowered = jax.jit(fn, in_shardings=shards,
                                  out_shardings=out_shards,
                                  donate_argnums=donate).lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            with trace.span("dryrun-compile", arch=arch, shape=shape_name):
                compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            rec["memory"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(mem, k)
            }
            args_b = rec["memory"].get("argument_size_in_bytes", 0)
            alias_b = rec["memory"].get("alias_size_in_bytes", 0)
            tmp_b = rec["memory"].get("temp_size_in_bytes", 0)
            out_b = rec["memory"].get("output_size_in_bytes", 0)
            rec["memory"]["per_device_total"] = args_b + tmp_b + (out_b - alias_b)
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax: one dict per program
            cost = cost[0] if cost else {}
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and (
                           "flops" in k or "bytes" in k or k in ("transcendentals",))}

        hlo = compiled.as_text()
        report = parse_hlo(hlo, n_partitions=n_dev)
        rec["collectives"] = report.summary()
        # execution-weighted (loop-trip-aware) flops/bytes — XLA's own
        # cost_analysis counts while bodies once (see inspector.hlo_cost)
        rec["hlo_cost"] = hlo_cost(hlo)
        rec["hlo_bytes"] = len(hlo)
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        trace.emit("dryrun-error", arch=arch, shape=shape_name,
                   error=rec["error"].splitlines()[0][:200])

    # ring dump into the artifact: the audit convention applied to the
    # launcher itself (ROADMAP PR 2 follow-up) — what lowered/compiled,
    # how long each stage took, and any error, machine-readable.  A
    # shared tracer only contributes this cell's events to this artifact.
    rec["trace"] = {"summary": trace.summary(),
                    "events": [e.to_dict() for e in trace.events()
                               if e.seq >= trace_start]}

    if verbose:
        flops = rec.get("cost", {}).get("flops", 0)
        mem_b = rec.get("memory", {}).get("per_device_total", 0)
        print(f"[{rec['status']:5s}] {arch} × {shape_name} × {rec['mesh']} "
              f"rules={run.rules} lower={rec.get('lower_s', 0):.1f}s "
              f"compile={rec.get('compile_s', 0):.1f}s "
              f"flops/dev={flops:.3e} mem/dev={mem_b/2**30:.2f}GiB "
              f"coll={rec.get('collectives', {}).get('total_moved_bytes', 0):.3e}B")
        if rec["status"] == "error":
            print("   ", rec["error"].splitlines()[0][:200])

    if out_dir:
        path = Path(out_dir)
        path.mkdir(parents=True, exist_ok=True)
        pod = "mp" if multi_pod else "sp"
        fname = f"{arch}__{shape_name}__{pod}__{run.rules}__{remat}.json"
        (path / fname).write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all applicable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default="auto")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default="EXPERIMENTS/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch in archs:
        shapes = [args.shape] if args.shape else applicable_shapes(arch)
        for shape in shapes:
            for mp in meshes:
                rec = dryrun_cell(arch, shape, multi_pod=mp, rules=args.rules,
                                  remat=args.remat, out_dir=args.out)
                failures += rec["status"] != "ok"
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
