"""Serving driver: continuous-batching engine over a smoke-scale model.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import reduced
from repro.core.registry import resolve_arch
from repro.models import build
from repro.serve.engine import Request, ServeEngine


def serve(arch: str, *, n_requests: int = 8, slots: int = 4,
          max_len: int = 96, max_new: int = 16, seed: int = 0) -> dict:
    cfg = reduced(resolve_arch(arch))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    engine = ServeEngine(model, params, slots=slots, max_len=max_len)

    rng = np.random.default_rng(seed)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(4, 17)).tolist(),
                max_new=max_new)
        for i in range(n_requests)
    ]
    t0 = time.time()
    done = engine.run(reqs)
    wall = time.time() - t0

    ttfts = [r.t_first - r.t_submit for r in done if r.t_first]
    return {
        "arch": cfg.name,
        "served": engine.stats.served,
        "decode_steps": engine.stats.decode_steps,
        "tokens_out": engine.stats.tokens_out,
        "mean_batch_occupancy": round(engine.stats.mean_occupancy, 2),
        "mean_ttft_s": round(float(np.mean(ttfts)), 4) if ttfts else None,
        "tokens_per_s": round(engine.stats.tokens_out / max(wall, 1e-9), 1),
        "wall_s": round(wall, 2),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    print(json.dumps(serve(args.arch, n_requests=args.requests,
                           slots=args.slots, max_len=args.max_len,
                           max_new=args.max_new), indent=1))


if __name__ == "__main__":
    main()
