"""Serving driver: paged-KV engine over a smoke-scale model.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --requests 8 --slots 4 [--temperature 0.8 --top-k 20 --top-p 0.95]

The paged path (prefix cache + chunked prefill + scheduler) is the
default for attention-cache families; ``--engine contiguous`` selects the
seed slot engine, which is also the automatic fallback for families the
chunked decode does not cover (ssm/hybrid/vlm/encdec) and the
dual-environment oracle for ``repro.serve.compare_engines``.

Both engines are driven through the unified request-lifecycle API
(``serve.api``): requests are submitted with per-request
``SamplingParams`` (greedy by default; counter-based PRNG keys make
sampled streams deterministic and engine-independent) and drained, and
per-request TTFT comes from the audit tracer's lifecycle events.

``--replicas N`` (N > 1) serves through ``repro.serve.cluster``: N paged
replicas behind prefix-affinity routing (``--routing`` selects the
policy; ``random`` deliberately misroutes so operators can watch the
``pathway-routing`` detector fire without changing a single token).

``--metrics-port`` starts the live observability endpoint
(``audit.metrics.MetricsServer``): a ``ServeMetrics`` registry and an
``EventLog`` subscribe to the audit tracer, so ``/metrics`` (Prometheus
text), ``/metrics.json`` (snapshot with deterministic quantiles),
``/events`` (filtered JSONL), ``/timeline`` (Chrome-trace JSON of the
reconstructed per-request phase timelines), ``/requests/<rid>`` (one
request's history + phase decomposition), and ``/healthz`` reflect the
run as it happens.  Port 0 picks an ephemeral port (reported in the
output); ``--metrics-linger`` keeps the endpoint up after the drain so
an operator can scrape the finished run.

``--trace-out FILE`` writes the same Chrome-trace-event JSON to disk
after the drain — load it in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing`` to see one track per replica/slot plus a queue
track (see ``docs/observability.md``).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.audit import (AuditContext, Evidence, EventLog, MetricsServer,
                         RunAudit, ServeMetrics, Tracer, attribution,
                         build_timelines, chrome_trace_bytes)
from repro.configs.base import reduced
from repro.core.registry import resolve_arch
from repro.models import build
from repro.serve import (ClusterEngine, PagedServeEngine, Request,
                         SamplingParams, ServeEngine)


def serve(arch: str, *, n_requests: int = 8, slots: int = 4,
          max_len: int = 96, max_new: int = 16, seed: int = 0,
          engine: str = "paged", block_size: int = 8,
          chunk: int = 4, shared_prefix: int = 0,
          use_prefix_cache: bool = True, kernel: str = "paged",
          swap: bool = True, replicas: int = 1, routing: str = "affinity",
          audit: bool = True, metrics_port: int | None = None,
          metrics_linger: float = 0.0, trace_out: str | None = None,
          temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
          sampling_seed: int = 0) -> dict:
    if trace_out is not None and not audit:
        raise ValueError("--trace-out reconstructs timelines from the "
                         "audit tracer; drop --no-audit")
    cfg = reduced(resolve_arch(arch))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    sampling = SamplingParams(temperature=temperature, top_k=top_k,
                              top_p=top_p, seed=sampling_seed)

    if engine == "paged" and cfg.family not in ("dense", "moe"):
        engine = "contiguous"   # no chunked path for stateful caches yet
    # cluster replicas are paged engines; anything that forces the
    # contiguous path also collapses the cluster to a single engine
    if engine != "paged":
        replicas = 1
    is_cluster = replicas > 1
    # a shared prefix shorter than one page cannot produce cache hits
    # (only full blocks register), so only declare the workload
    # shared-prefix when a hit is actually possible
    run_audit = RunAudit(AuditContext(
        workload="serve", family=cfg.family, arch=cfg.name,
        shared_prefix=shared_prefix >= block_size)) if audit else None
    tracer = run_audit.tracer if run_audit else None
    replica_tracers = [Tracer() for _ in range(replicas)] if is_cluster else []

    # live observability: metrics + event log fed from the tracer's
    # subscription hook, exposed over HTTP while the engine runs.  A
    # cluster attaches one replica-labelled ServeMetrics per replica
    # tracer to the SAME registry, so the single endpoint serves every
    # replica's series side by side.
    metrics = server = None
    if metrics_port is not None:
        if tracer is None:
            raise ValueError("--metrics-port needs the audit tracer; "
                             "drop --no-audit")
        metrics = ServeMetrics()
        metrics.attach(tracer)
        for i, rt in enumerate(replica_tracers):
            ServeMetrics(metrics.registry,
                         labels={"replica": str(i)}).attach(rt)
        log = EventLog()
        tracer.subscribe(log.append)
        # replica tracers carry the admit/prefill-done/finish lifecycle
        # a cluster's front tracer never sees — /timeline and
        # /requests/<rid> need the merged stream
        for rt in replica_tracers:
            rt.subscribe(log.append)
        server = MetricsServer(metrics.registry, log)
        bound_port = server.serve(port=metrics_port)
    if is_cluster:
        eng = ClusterEngine(model, params, replicas=replicas, slots=slots,
                            max_len=max_len, block_size=block_size,
                            chunk=chunk, routing=routing,
                            use_prefix_cache=use_prefix_cache,
                            kernel=kernel, tracer=tracer,
                            replica_tracers=replica_tracers)
    elif engine == "paged":
        eng = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                               block_size=block_size, chunk=chunk,
                               use_prefix_cache=use_prefix_cache,
                               kernel=kernel, swap=swap, tracer=tracer)
    else:
        eng = ServeEngine(model, params, slots=slots, max_len=max_len,
                          tracer=tracer)

    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=shared_prefix).tolist()
    reqs = [
        Request(rid=i,
                prompt=prefix + rng.integers(
                    0, cfg.vocab_size, size=rng.integers(4, 17)).tolist(),
                max_new=max_new, sampling=sampling)
        for i in range(n_requests)
    ]
    t0 = time.time()
    for req in reqs:
        eng.submit(req)
    done = eng.drain()
    wall = time.time() - t0

    ttfts = [r.t_first - r.t_submit for r in done if r.t_first]
    rep = eng.report()
    out = {
        "arch": cfg.name,
        "engine": rep["engine"],
        "sampling": sampling.describe(),
        "served": rep["served"],
        "decode_steps": rep["decode_steps"],
        "tokens_out": rep["tokens_out"],
        "mean_batch_occupancy": rep["mean_batch_occupancy"],
        "mean_ttft_s": round(float(np.mean(ttfts)), 4) if ttfts else None,
        "tokens_per_s": round(rep["tokens_out"] / max(wall, 1e-9), 1),
        "wall_s": round(wall, 2),
    }
    if is_cluster:
        out.update({k: rep[k] for k in
                    ("replicas", "routing", "routed", "routed_affinity",
                     "routed_spills", "shared_hit_rate", "prefix_hit_rate",
                     "preemptions", "kernel", "summary_rebuilds")})
    elif engine == "paged":
        out.update({k: rep[k] for k in
                    ("prefill_tokens", "cached_tokens", "prefix_hit_rate",
                     "page_peak_utilization", "preemptions", "kernel",
                     "swap", "swap_restore_rate",
                     "restored_tokens", "recompute_tokens")})
    if run_audit is not None:
        lat = Evidence(tracer=run_audit.tracer).request_latencies()
        if lat:
            ttft_ticks = [l["ttft_ticks"] for l in lat.values()]
            out["mean_ttft_ticks"] = round(float(np.mean(ttft_ticks)), 2)
            out["max_ttft_ticks"] = round(float(np.max(ttft_ticks)), 2)
        timelines = build_timelines(tracer, *replica_tracers)
        att = attribution(timelines)
        if att:
            out["attribution"] = {
                "p99_ttft_ticks": att["p99_ttft_ticks"],
                "dominant_phase": att["dominant_phase"],
                "p99_shares": {k: round(v, 3)
                               for k, v in att["p99_shares"].items()},
                "preempted_share": round(att["preempted_share"], 3),
            }
        if trace_out is not None:
            data = chrome_trace_bytes(timelines)
            Path(trace_out).write_bytes(data)
            out["trace_out"] = {"path": trace_out,
                                "requests": len(timelines),
                                "bytes": len(data)}
        diag = run_audit.finish(engine_report=eng.report(), source="serve")
        out["audit"] = {
            "findings": diag.findings,
            "worst": diag.worst,
            "gate_ok": diag.gate(),
            "trace": run_audit.tracer.summary()["counts"],
        }
    if server is not None:
        metrics.observe_report(eng.report())
        out["metrics"] = {
            "port": bound_port,
            "endpoints": ["/metrics", "/metrics.json", "/events",
                          "/timeline", "/requests/<rid>", "/healthz"],
            "finished": metrics.finished.value,
            "p99_ttft_bucket": metrics.ttft.quantile(0.99),
        }
        if metrics_linger > 0:
            time.sleep(metrics_linger)
        server.close()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--engine", choices=["paged", "contiguous"],
                    default="paged")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="length of a prompt prefix shared by all requests")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples with counter-based "
                         "per-request PRNG (deterministic, replayable)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k most likely tokens (0 = no limit)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus bound in (0, 1]")
    ap.add_argument("--sampling-seed", type=int, default=0)
    ap.add_argument("--kernel", choices=["paged", "gather"], default="paged",
                    help="paged-engine KV pathway: attend through the "
                         "device page table (default) or fall back to the "
                         "dense working-cache gather — the latter exists "
                         "so operators can watch the pathway-kernel "
                         "detector fire")
    ap.add_argument("--swap", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="host KV swap tier for preempted requests "
                         "(--no-swap recomputes on readmission instead — "
                         "token streams do not change; the pathway-tiering "
                         "detector exists to catch exactly that)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="> 1 serves through a ClusterEngine: N paged "
                         "replicas behind prefix-affinity routing, one "
                         "metrics endpoint with replica-labelled series")
    ap.add_argument("--routing",
                    choices=["affinity", "round_robin", "random"],
                    default="affinity",
                    help="cluster routing policy (random exists so "
                         "operators can watch the pathway-routing "
                         "detector fire; token streams do not change)")
    ap.add_argument("--no-prefix-cache", dest="use_prefix_cache",
                    action="store_false",
                    help="disable prefix-KV reuse (the audit flags this "
                         "on shared-prefix workloads)")
    ap.add_argument("--no-audit", dest="audit", action="store_false",
                    help="skip runtime pathway auditing")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics, /metrics.json, /events and "
                         "/healthz on this port while the run is live "
                         "(0 = ephemeral; reported in the output)")
    ap.add_argument("--metrics-linger", type=float, default=0.0,
                    help="seconds to keep the metrics endpoint up after "
                         "the drain completes")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write the per-request phase timelines as "
                         "Chrome-trace-event JSON (open in Perfetto or "
                         "chrome://tracing); needs the audit tracer")
    args = ap.parse_args()
    res = serve(args.arch, n_requests=args.requests,
                slots=args.slots, max_len=args.max_len,
                max_new=args.max_new, engine=args.engine,
                block_size=args.block_size, chunk=args.chunk,
                shared_prefix=args.shared_prefix,
                use_prefix_cache=args.use_prefix_cache, kernel=args.kernel,
                swap=args.swap,
                replicas=args.replicas, routing=args.routing,
                audit=args.audit, metrics_port=args.metrics_port,
                metrics_linger=args.metrics_linger,
                trace_out=args.trace_out,
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, sampling_seed=args.sampling_seed)
    print(json.dumps(res, indent=1))
    if res.get("audit") and not res["audit"]["gate_ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
