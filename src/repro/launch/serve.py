"""Serving driver: paged-KV engine over a smoke-scale model.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --requests 8 --slots 4

The paged path (prefix cache + chunked prefill + scheduler) is the
default for attention-cache families; ``--engine contiguous`` selects the
seed slot engine, which is also the automatic fallback for families the
chunked decode does not cover (ssm/hybrid/vlm/encdec) and the
dual-environment oracle for ``repro.serve.compare_engines``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import reduced
from repro.core.registry import resolve_arch
from repro.models import build
from repro.serve.engine import PagedServeEngine, Request, ServeEngine


def serve(arch: str, *, n_requests: int = 8, slots: int = 4,
          max_len: int = 96, max_new: int = 16, seed: int = 0,
          engine: str = "paged", block_size: int = 8,
          chunk: int = 4, shared_prefix: int = 0) -> dict:
    cfg = reduced(resolve_arch(arch))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))

    if engine == "paged" and cfg.family not in ("dense", "moe"):
        engine = "contiguous"   # no chunked path for stateful caches yet
    if engine == "paged":
        eng = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                               block_size=block_size, chunk=chunk)
    else:
        eng = ServeEngine(model, params, slots=slots, max_len=max_len)

    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=shared_prefix).tolist()
    reqs = [
        Request(rid=i,
                prompt=prefix + rng.integers(
                    0, cfg.vocab_size, size=rng.integers(4, 17)).tolist(),
                max_new=max_new)
        for i in range(n_requests)
    ]
    t0 = time.time()
    done = eng.run(reqs)
    wall = time.time() - t0

    ttfts = [r.t_first - r.t_submit for r in done if r.t_first]
    out = {
        "arch": cfg.name,
        "engine": engine,
        "served": eng.stats.served,
        "decode_steps": eng.stats.decode_steps,
        "tokens_out": eng.stats.tokens_out,
        "mean_batch_occupancy": round(eng.stats.mean_occupancy, 2),
        "mean_ttft_s": round(float(np.mean(ttfts)), 4) if ttfts else None,
        "tokens_per_s": round(eng.stats.tokens_out / max(wall, 1e-9), 1),
        "wall_s": round(wall, 2),
    }
    if engine == "paged":
        rep = eng.report()
        out.update({k: rep[k] for k in
                    ("prefill_tokens", "cached_tokens", "prefix_hit_rate",
                     "page_peak_utilization", "preemptions")})
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--engine", choices=["paged", "contiguous"],
                    default="paged")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="length of a prompt prefix shared by all requests")
    args = ap.parse_args()
    print(json.dumps(serve(args.arch, n_requests=args.requests,
                           slots=args.slots, max_len=args.max_len,
                           max_new=args.max_new, engine=args.engine,
                           block_size=args.block_size, chunk=args.chunk,
                           shared_prefix=args.shared_prefix), indent=1))


if __name__ == "__main__":
    main()
