"""Serving driver: paged-KV engine over a smoke-scale model.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --requests 8 --slots 4

The paged path (prefix cache + chunked prefill + scheduler) is the
default for attention-cache families; ``--engine contiguous`` selects the
seed slot engine, which is also the automatic fallback for families the
chunked decode does not cover (ssm/hybrid/vlm/encdec) and the
dual-environment oracle for ``repro.serve.compare_engines``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.audit import AuditContext, RunAudit
from repro.configs.base import reduced
from repro.core.registry import resolve_arch
from repro.models import build
from repro.serve.engine import PagedServeEngine, Request, ServeEngine


def serve(arch: str, *, n_requests: int = 8, slots: int = 4,
          max_len: int = 96, max_new: int = 16, seed: int = 0,
          engine: str = "paged", block_size: int = 8,
          chunk: int = 4, shared_prefix: int = 0,
          use_prefix_cache: bool = True, audit: bool = True) -> dict:
    cfg = reduced(resolve_arch(arch))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))

    if engine == "paged" and cfg.family not in ("dense", "moe"):
        engine = "contiguous"   # no chunked path for stateful caches yet
    # a shared prefix shorter than one page cannot produce cache hits
    # (only full blocks register), so only declare the workload
    # shared-prefix when a hit is actually possible
    run_audit = RunAudit(AuditContext(
        workload="serve", family=cfg.family, arch=cfg.name,
        shared_prefix=shared_prefix >= block_size)) if audit else None
    tracer = run_audit.tracer if run_audit else None
    if engine == "paged":
        eng = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                               block_size=block_size, chunk=chunk,
                               use_prefix_cache=use_prefix_cache,
                               tracer=tracer)
    else:
        eng = ServeEngine(model, params, slots=slots, max_len=max_len,
                          tracer=tracer)

    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=shared_prefix).tolist()
    reqs = [
        Request(rid=i,
                prompt=prefix + rng.integers(
                    0, cfg.vocab_size, size=rng.integers(4, 17)).tolist(),
                max_new=max_new)
        for i in range(n_requests)
    ]
    t0 = time.time()
    done = eng.run(reqs)
    wall = time.time() - t0

    ttfts = [r.t_first - r.t_submit for r in done if r.t_first]
    out = {
        "arch": cfg.name,
        "engine": engine,
        "served": eng.stats.served,
        "decode_steps": eng.stats.decode_steps,
        "tokens_out": eng.stats.tokens_out,
        "mean_batch_occupancy": round(eng.stats.mean_occupancy, 2),
        "mean_ttft_s": round(float(np.mean(ttfts)), 4) if ttfts else None,
        "tokens_per_s": round(eng.stats.tokens_out / max(wall, 1e-9), 1),
        "wall_s": round(wall, 2),
    }
    if engine == "paged":
        rep = eng.report()
        out.update({k: rep[k] for k in
                    ("prefill_tokens", "cached_tokens", "prefix_hit_rate",
                     "page_peak_utilization", "preemptions")})
    if run_audit is not None:
        diag = run_audit.finish(engine_report=eng.report(), source="serve")
        out["audit"] = {
            "findings": diag.findings,
            "worst": diag.worst,
            "gate_ok": diag.gate(),
            "trace": run_audit.tracer.summary()["counts"],
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--engine", choices=["paged", "contiguous"],
                    default="paged")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="length of a prompt prefix shared by all requests")
    ap.add_argument("--no-prefix-cache", dest="use_prefix_cache",
                    action="store_false",
                    help="disable prefix-KV reuse (the audit flags this "
                         "on shared-prefix workloads)")
    ap.add_argument("--no-audit", dest="audit", action="store_false",
                    help="skip runtime pathway auditing")
    args = ap.parse_args()
    res = serve(args.arch, n_requests=args.requests,
                slots=args.slots, max_len=args.max_len,
                max_new=args.max_new, engine=args.engine,
                block_size=args.block_size, chunk=args.chunk,
                shared_prefix=args.shared_prefix,
                use_prefix_cache=args.use_prefix_cache, audit=args.audit)
    print(json.dumps(res, indent=1))
    if res.get("audit") and not res["audit"]["gate_ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
