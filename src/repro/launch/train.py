"""End-to-end training driver.

Wire-up (PMIx analogue) → mesh bind → manifest capture → data pipeline →
jitted train step → checkpoint/restart loop with health + straggler
tracking.  Run directly for the CPU-scale example:

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --smoke --steps 20 --ckpt-every 10 --out /tmp/run
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.audit import AuditContext, RunAudit
from repro.configs import SHAPES, TINY_MESH
from repro.configs.base import RunConfig, ShapeConfig, TrainConfig, reduced
from repro.core import Diagnostics, Manifest, PortableEnv, parse_hlo
from repro.core.bootstrap import WireUp, init_distributed
from repro.core.registry import resolve_arch
from repro.data.pipeline import DataConfig, DataPipeline
from repro.launch import bind as B
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import build
from repro.parallel import bind as ctx_bind, rules_for
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerTracker
from repro.train.step import init_train_state, make_train_step


def train(arch: str, *, smoke: bool = True, steps: int = 20,
          seq_len: int = 128, global_batch: int = 8, ckpt_every: int = 10,
          out_dir: str = "/tmp/repro_train", production_mesh: bool = False,
          resume: bool = False, seed: int = 0,
          total_steps: int | None = None) -> dict:
    wireup = init_distributed(WireUp.from_env())
    cfg = reduced(resolve_arch(arch)) if smoke else resolve_arch(arch)
    shape = ShapeConfig("train", "train", seq_len, global_batch)
    horizon = total_steps or steps  # LR schedule horizon: fixed across
    # restarts so a resumed run follows the identical schedule
    tc = TrainConfig(total_steps=horizon, warmup_steps=max(horizon // 10, 1),
                     remat="full", seed=seed)
    run = RunConfig(model=cfg, shape=shape, train=tc)

    mesh = (make_production_mesh() if production_mesh
            else make_mesh(TINY_MESH))
    model = build(cfg)
    manifest = Manifest(PortableEnv.capture(cfg, shape, tc, run.rules)).bind(mesh)

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    ckpt = CheckpointManager(out / "ckpt")
    diag = Diagnostics()
    audit = RunAudit(AuditContext(workload="train", family=cfg.family,
                                  arch=cfg.name,
                                  mesh=tuple(mesh.devices.shape)))

    with ctx_bind(mesh, rules_for(run)):
        step_fn = make_train_step(model, run)
        st_sh = B.state_shardings(model, mesh)
        b_sh = B.batch_shardings(model, shape, mesh)
        jitted = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, None), donate_argnums=(0,))

        start_step = 0
        state = init_train_state(model, jax.random.PRNGKey(seed))
        if resume and ckpt.latest_step() is not None:
            start_step = ckpt.latest_step()
            state = ckpt.restore(start_step, like=state, shardings=st_sh)
            audit.tracer.emit("ckpt-restore", step=start_step)
            print(f"[train] resumed from step {start_step}")
        state = jax.device_put(state, st_sh)

        # attest the compiled program (transport inspection on first step)
        lowered = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, None), donate_argnums=(0,)
                          ).lower(jax.eval_shape(lambda: state),
                                  model.input_specs(shape))
        compiled = lowered.compile()
        report = parse_hlo(compiled.as_text(), mesh.devices.size)
        manifest.attest(hlo_text=compiled.as_text(),
                        collectives=report.summary())
        diag.extend(report.findings, "train-step-hlo")
        (out / "manifest.json").write_text(manifest.to_json())

        data = DataPipeline(
            DataConfig(cfg.vocab_size, seq_len, global_batch, seed=seed,
                       n_hosts=jax.process_count(),
                       host_id=jax.process_index()),
            start_step=start_step)
        tracker = StragglerTracker(n_hosts=max(jax.process_count(), 1))

        losses = []
        t_start = time.time()
        for _ in range(start_step, steps):
            step_id, host_batch = next(data)
            batch = jax.device_put(host_batch, b_sh)
            t0 = time.perf_counter()
            with audit.tracer.span("train-step", step=step_id) as ev:
                state, metrics = jitted(state, batch)
                loss = float(metrics["loss"])
                ev["loss"] = loss
            dt = time.perf_counter() - t0
            tracker.observe({jax.process_index(): dt})
            losses.append(loss)
            if (step_id + 1) % ckpt_every == 0 or step_id + 1 == steps:
                with audit.tracer.span("ckpt-save", step=step_id + 1):
                    ckpt.save(step_id + 1, state,
                              extra={"loss": loss,
                                     "image_hash":
                                     manifest.portable.image_hash})
        data.close()
        # pathway expectations over the attested transport report: the
        # same HLO the manifest records is judged against what this
        # (family, mesh, workload) should emit
        audit.finish(diag, transport=report, source="train-audit")

    result = {
        "arch": cfg.name,
        "steps": steps,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "loss_decreased": bool(losses and losses[-1] < losses[0]),
        "wall_s": round(time.time() - t_start, 2),
        "fleet_efficiency": tracker.fleet_efficiency(),
        "diagnostics": diag.worst,
        "audit": {
            "trace": audit.tracer.summary()["counts"],
            "findings": diag.findings,
            "gate_ok": diag.gate(),
        },
        "image_hash": manifest.portable.image_hash,
        "wireup": vars(wireup),
    }
    (out / "result.json").write_text(json.dumps(result, indent=1, default=str))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--out", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()
    res = train(args.arch, smoke=args.smoke, steps=args.steps,
                seq_len=args.seq_len, global_batch=args.global_batch,
                ckpt_every=args.ckpt_every, out_dir=args.out,
                resume=args.resume, production_mesh=args.production_mesh)
    print(json.dumps(res, indent=1, default=str))


if __name__ == "__main__":
    main()
