"""Slurm launch-script generation — the deployment half of the PMIx story.

The paper's operational claim: "containerized jobs are submitted to Slurm
identically to native jobs, with the sole modification of specifying the
PMIx wire-up protocol" (--mpi=pmix).  The analogue for a multi-host JAX
job: identical sbatch scripts whose only coupling to the host is the
coordinator triple that bootstrap.WireUp reads from SLURM_* variables.
``emit_sbatch`` writes that script for any (arch, shape, mesh) cell.
"""
from __future__ import annotations

from pathlib import Path

TEMPLATE = """#!/bin/bash
#SBATCH --job-name=repro-{arch}-{shape}
#SBATCH --nodes={nodes}
#SBATCH --ntasks-per-node=1
#SBATCH --cpus-per-task={cpus}
#SBATCH --time={walltime}
#SBATCH --output=%x-%j.out
{extra_directives}
# One process per host; each host drives its local TPU devices.  The only
# host-coupled configuration is the wire-up triple, resolved from SLURM_*
# by repro.core.bootstrap.WireUp (the --mpi=pmix analogue).
export REPRO_COORD_PORT={coord_port}
export JAX_PLATFORMS={platform}

srun --kill-on-bad-exit=1 \\
  {container_prefix}python -m repro.launch.{entry} \\
    --arch {arch} {entry_args}
"""


def emit_sbatch(arch: str, shape: str, *, nodes: int = 64,
                entry: str = "train", entry_args: str = "--full",
                platform: str = "tpu", cpus: int = 32,
                walltime: str = "04:00:00", coord_port: int = 9876,
                container_image: str | None = None,
                out_dir: str | Path = "launch_scripts") -> Path:
    """Write an sbatch script.  With ``container_image`` set, the srun line
    wraps the command in the container runtime exactly the way the paper
    launches Apptainer images (image immutable, wire-up from the host)."""
    prefix = ""
    if container_image:
        prefix = f"apptainer exec --nv {container_image} "
    text = TEMPLATE.format(
        arch=arch, shape=shape, nodes=nodes, cpus=cpus, walltime=walltime,
        coord_port=coord_port, platform=platform, entry=entry,
        entry_args=entry_args, container_prefix=prefix,
        extra_directives="",
    )
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{arch}__{shape}__{entry}.sbatch"
    path.write_text(text)
    return path


def emit_all(out_dir: str | Path = "launch_scripts") -> list[Path]:
    from repro.core.registry import all_cells

    paths = []
    for arch, shape in all_cells():
        entry = "train" if shape == "train_4k" else "serve"
        paths.append(emit_sbatch(arch, shape, entry=entry,
                                 out_dir=out_dir))
    return paths


if __name__ == "__main__":
    for p in emit_all():
        print(p)
