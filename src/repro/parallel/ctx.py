"""Sharding context: the late "host binding" for model code.

Model code never names mesh axes directly; it annotates activations with
*logical* axis names (``constrain(x, ("act_batch", "act_seq", None))``).
The binding from logical names to physical mesh axes is installed by the
step factory for the duration of tracing — the same model code lowers
against any mesh, which is exactly the paper's portable-image property.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current():
    return getattr(_state, "ctx", None)


class ShardCtx:
    def __init__(self, mesh: Mesh, rules: dict[str, tuple[str, ...] | str | None]):
        self.mesh = mesh
        self.rules = rules
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def resolve(self, logical: Sequence[str | None], shape: Sequence[int] | None = None) -> P:
        """Map logical axis names to a PartitionSpec, dropping any mapping
        that would not divide the corresponding dimension evenly and
        de-duplicating mesh axes (first use wins)."""
        used: set[str] = set()
        parts = []
        for i, name in enumerate(logical):
            spec = self.rules.get(name) if name else None
            if spec is None:
                parts.append(None)
                continue
            axes = (spec,) if isinstance(spec, str) else tuple(spec)
            axes = tuple(a for a in axes if a in self.axis_sizes and a not in used)
            if not axes:
                parts.append(None)
                continue
            size = 1
            for a in axes:
                size *= self.axis_sizes[a]
            if shape is not None and shape[i] % size != 0:
                # Uneven — replicate rather than let GSPMD pad implicitly.
                parts.append(None)
                continue
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else axes)
        return P(*parts)


@contextlib.contextmanager
def bind(mesh: Mesh, rules: dict):
    prev = _current()
    _state.ctx = ShardCtx(mesh, rules)
    try:
        yield _state.ctx
    finally:
        _state.ctx = prev


def mesh_of(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer jax; older versions
    default every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def shard_map_compat(body, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: newer jax exposes it at the
    top level with ``check_vma``; older jax has the experimental module
    with ``check_rep``.  Replication checks stay off either way (the
    bodies use collectives XLA cannot always infer replication for)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def constrain(x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
    """Annotate ``x`` with the sharding its logical axes resolve to.
    No-op when no context is bound (single-device smoke tests)."""
    ctx = _current()
    if ctx is None:
        return x
    spec = ctx.resolve(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def resolve(logical: Sequence[str | None], shape: Sequence[int] | None = None) -> P:
    ctx = _current()
    if ctx is None:
        return P(*([None] * len(logical)))
    return ctx.resolve(logical, shape)


def sharding_for(logical: Sequence[str | None], shape: Sequence[int] | None = None):
    ctx = _current()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, ctx.resolve(logical, shape))
