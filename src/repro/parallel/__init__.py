from repro.parallel.ctx import bind, constrain, resolve, sharding_for, ShardCtx
from repro.parallel.rules import RULESETS, rules_for

__all__ = [
    "bind", "constrain", "resolve", "sharding_for", "ShardCtx",
    "RULESETS", "rules_for",
]
