"""Logical-axis → mesh-axis rule tables.

These tables are the single lever the perf pass turns: model code names
logical axes; a rule set binds them to the physical mesh.  ``resolve`` in
ctx.py drops any binding that does not divide the dimension evenly, and
deduplicates mesh axes per tensor (first dimension wins), so e.g. for
``long_500k`` (batch=1) the ``act_batch`` rule drops out and ``act_seq``
picks up the data axes — sequence parallelism falls out of the same table.

Weight logical axes:
  embed      d_model dim of every projection (FSDP axis in training)
  heads_out  flattened n_heads·head_dim output of Q and attn-out input
  kv_out     flattened kv_heads·head_dim
  mlp        d_ff
  vocab      (padded) vocabulary
  experts    expert count (EP)
  ssm_inner  Mamba2 d_inner / conv channels
  ssm_heads  Mamba2 head count
  layers     stacked-scan dim (never sharded)

Activation logical axes: act_batch, act_seq, act_embed, act_heads, act_kv,
act_mlp, act_vocab, act_experts, act_inner; cache axes: cache_batch,
cache_seq, cache_kv.
"""
from __future__ import annotations

from repro.configs.base import RunConfig

Rule = dict[str, tuple[str, ...] | str | None]

_TP = "model"
_DP = ("pod", "data")

# Production training rules: 2D FSDP(pod,data) × TP(model); ZeRO-3 optimizer
# sharding falls out because opt state shares the param specs.
TRAIN: Rule = {
    "embed": _DP,
    "heads_out": _TP, "kv_out": _TP, "mlp": _TP, "vocab": _TP,
    "experts": _TP, "ssm_inner": _TP, "ssm_heads": _TP,
    "layers": None, "groups": None,
    "act_batch": _DP, "act_seq": _DP, "act_embed": None,
    # act_res: the residual stream between blocks (the scan carry that is
    # saved for backward).  Sequence-sharding it over the model axis is
    # Megatron sequence parallelism: GSPMD inserts the all-gather at block
    # entry and the reduce-scatter after the block's row-parallel matmul,
    # and the per-layer saved activations shrink by the TP width.
    "act_res": _TP,
    "act_heads": _TP, "act_kv": _TP, "act_mlp": _TP, "act_vocab": _TP,
    "act_experts": _TP, "act_inner": _TP,
    "cache_batch": _DP, "cache_seq": None, "cache_kv": _TP,
    "cache_seq_tp": _TP,
}

# Pure DP+TP without FSDP — the "as-shipped portable image" the paper's
# container gives you before any host-side tuning.  Kept for the §Perf
# baseline contrast on small models (large models OOM, which memory_analysis
# proves — that is itself a §Perf data point).
TRAIN_NO_FSDP: Rule = dict(TRAIN, embed=None)

# Without sequence-parallel residual sharding (per-layer saved activations
# replicated over the model axis) — §Perf contrast.
TRAIN_NO_SP: Rule = dict(TRAIN, act_res=None)

# Serving: weights TP-only (replicated over data — decode all-gathers of
# FSDP weights every token would dominate); cache sharded batch×heads; for
# batch=1 long-context the cache_seq rule picks up the data axes.
SERVE: Rule = {
    "embed": None,
    "heads_out": _TP, "kv_out": _TP, "mlp": _TP, "vocab": _TP,
    "experts": _TP, "ssm_inner": _TP, "ssm_heads": _TP,
    "layers": None, "groups": None,
    "act_batch": _DP, "act_seq": _DP, "act_embed": None,
    "act_res": None,  # decode activations are tiny; prefill re-adds SP below
    "act_heads": _TP, "act_kv": _TP, "act_mlp": _TP, "act_vocab": _TP,
    "act_experts": _TP, "act_inner": _TP,
    "cache_batch": _DP, "cache_seq": _DP, "cache_kv": _TP,
    "cache_seq_tp": _TP,
}

# Prefill benefits from sequence-parallel residuals like training does.
SERVE_SP: Rule = dict(SERVE, act_res=_TP)

# Prefill for very large models: weights additionally FSDP-sharded over the
# data axes (per-layer gathers amortize over the whole prompt; TP-only
# weights alone would not leave HBM headroom for the 32k activations).
SERVE_SP_FSDP: Rule = dict(SERVE_SP, embed=_DP)

# Serving with weights additionally sharded over data (for models whose
# TP-only weights do not fit); decode then pays per-layer weight gathers.
SERVE_FSDP: Rule = dict(SERVE, embed=_DP)

RULESETS: dict[str, Rule] = {
    "train": TRAIN,
    "train_no_fsdp": TRAIN_NO_FSDP,
    "train_no_sp": TRAIN_NO_SP,
    "serve": SERVE,
    "serve_sp": SERVE_SP,
    "serve_sp_fsdp": SERVE_SP_FSDP,
    "serve_fsdp": SERVE_FSDP,
}

# TP-only weights above this per-device size force FSDP prefill sharding.
_PREFILL_FSDP_BYTES = 3 * 2**30


def rules_for(run: RunConfig) -> Rule:
    name = run.rules
    if name in ("auto", "baseline"):
        if run.shape.is_train:
            name = "train"
        elif run.shape.kind == "prefill":
            tp = run.mesh.axis_size("model")
            w_dev = 2 * run.model.param_count() / max(tp, 1)
            name = ("serve_sp_fsdp" if w_dev > _PREFILL_FSDP_BYTES
                    else "serve_sp")
        else:
            name = "serve"
    return RULESETS[name]
