"""Shared layer primitives: norms, RoPE, MLPs, embeddings, loss.

All functions are pure; parameters arrive as pytrees declared by the
``*_specs`` constructors so the same declaration drives abstract lowering,
real initialization and partitioning (models/params.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.parallel.ctx import constrain, shard_map_compat


def ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------- norms


def rmsnorm_spec(d: int, layers: int | None = None) -> P.ParamSpec:
    return P.scale(d, layers)


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


# ---------------------------------------------------------------- rope


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [..., seq, heads, head_dim], pos: [..., seq]."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[..., :, None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return out


def sinusoidal_positions(seq: int, d: int, offset: jax.Array | int = 0) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (stub for learned tables)."""
    pos = jnp.arange(seq)[:, None] + offset
    dim = jnp.arange(d // 2)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(jnp.bfloat16)


# ---------------------------------------------------------------- MLP


def swiglu_specs(cfg: ModelConfig, layers: int | None, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "gate": P.dense(d, f, "embed", "mlp", layers),
        "up": P.dense(d, f, "embed", "mlp", layers),
        "down": P.dense(f, d, "mlp", "embed", layers),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    g, u = sp_col_projects(x, (p["gate"], p["up"]), ("act_mlp", "act_mlp"))
    h = jax.nn.silu(g) * u
    h = constrain(h, ("act_batch", "act_seq", "act_mlp"))
    return rs_project(h, p["down"], "act_mlp")


def gelu_mlp_specs(cfg: ModelConfig, layers: int | None) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "up": P.dense(d, f, "embed", "mlp", layers),
        "down": P.dense(f, d, "mlp", "embed", layers),
    }


def gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    (u,) = sp_col_projects(x, (p["up"],), ("act_mlp",))
    h = jax.nn.gelu(u)
    h = constrain(h, ("act_batch", "act_seq", "act_mlp"))
    return rs_project(h, p["down"], "act_mlp")


# ---------------------------------------------------------------- embed / head


def embed_specs(cfg: ModelConfig) -> dict:
    v, d = cfg.padded_vocab, cfg.d_model
    specs = {"tok": P.ParamSpec((v, d), ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        specs["head"] = P.dense(d, v, "embed", "vocab")
    return specs


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    return constrain(x, ("act_batch", "act_seq", None))


def logits_from(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = (x @ w).astype(jnp.float32)
    return constrain(logits, ("act_batch", "act_seq", "act_vocab"))


# ---------------------------------------------------------------- loss


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int,
                  z_loss: float = 0.0) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Mean next-token CE over all positions; padded vocab ids masked."""
    v_pad = logits.shape[-1]
    if v_pad > vocab_size:
        mask = jnp.arange(v_pad) < vocab_size
        logits = jnp.where(mask, logits, -1e9)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    aux = {"nll": loss}
    if z_loss:
        zl = z_loss * jnp.mean(lse**2)
        aux["z_loss"] = zl
        loss = loss + zl
    return loss, aux


# ---------------------------------------------------------------- GQA geometry


@dataclasses.dataclass(frozen=True)
class HeadGeom:
    """Padding geometry making GQA shardable over a ``tp``-wide model axis.

    train/prefill compute: kv replicated over tp; q padded on the group dim
      to ``g_pad`` so that ``kv·g_pad % tp == 0``  (H_run = kv·g_pad).
    decode cache: kv zero-padded to ``kv_pad = ceil_mult(kv, tp)`` so the
      cache head dim itself shards     (H_dec = kv_pad·g).
    """

    n_heads: int
    n_kv: int
    head_dim: int
    tp: int

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv

    @property
    def g_pad(self) -> int:
        g = self.group
        while (self.n_kv * g) % self.tp:
            g += 1
        return g

    @property
    def h_run(self) -> int:
        return self.n_kv * self.g_pad

    @property
    def kv_pad(self) -> int:
        return ceil_mult(self.n_kv, self.tp)

    @property
    def h_dec(self) -> int:
        return self.kv_pad * self.group


def head_geom(cfg: ModelConfig, tp: int) -> HeadGeom:
    return HeadGeom(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, tp)


def pad_group_dim(w: jax.Array, geom: HeadGeom, axis_is_out: bool) -> jax.Array:
    """Zero-pad a [*, H·hd] (or [H·hd, *]) projection to the padded run
    layout [*, kv·g_pad·hd] keeping q heads grouped by their kv head."""
    if geom.g_pad == geom.group:
        return w
    hd, kv, g, gp = geom.head_dim, geom.n_kv, geom.group, geom.g_pad
    if axis_is_out:
        d = w.shape[0]
        w4 = w.reshape(d, kv, g, hd)
        w4 = jnp.pad(w4, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
        return w4.reshape(d, kv * gp * hd)
    d = w.shape[1]
    w4 = w.reshape(kv, g, hd, d)
    w4 = jnp.pad(w4, ((0, 0), (0, gp - g), (0, 0), (0, 0)))
    return w4.reshape(kv * gp * hd, d)


# ------------------------------------------------ explicit SP transitions
#
# Megatron sequence parallelism needs exactly two collectives per
# block half: all-gather(seq) at entry, reduce-scatter(seq) after the
# row-parallel projection.  GSPMD (without the GPU pipeline's
# ReduceScatterCreator pass) instead emits fp32 full-activation
# all-reduces — measured 4–8x the wire bytes on the train cells
# (EXPERIMENTS.md §Perf).  These helpers make the transitions explicit
# and bf16 via shard_map; they are no-ops whenever the residual stream
# is not sequence-sharded (single device, serve rules, indivisible dims).


@jax.custom_vjp
def bf16_tangent(x):
    return x


def _bf16_tangent_fwd(x):
    return x, None


def _bf16_tangent_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


bf16_tangent.defvjp(_bf16_tangent_fwd, _bf16_tangent_bwd)


def _sp_ctx(x_shape):
    from repro.parallel.ctx import _current

    ctx = _current()
    if ctx is None:
        return None
    tp = ctx.axis_sizes.get("model", 1)
    if tp <= 1 or ctx.rules.get("act_res") != "model":
        return None
    spec = ctx.resolve(("act_batch", "act_res", None), x_shape)
    if spec[1] != "model":
        return None
    return ctx, tp, spec


@jax.custom_jvp
def opt_barrier(x: jax.Array) -> jax.Array:
    """``jax.lax.optimization_barrier`` with a defined derivative (older
    jax has no AD rules for the primitive).  The tangent passes through
    un-barriered: identity is trivially transposable, and the barrier's
    job here — pinning the convert below the gather — is a forward-pass
    concern."""
    return jax.lax.optimization_barrier(x)


@opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jax.lax.optimization_barrier(x), t


def sp_gather_seq(x: jax.Array) -> jax.Array:
    """[B, S(seq-sharded over model), D] -> [B, S, D] replicated over
    model (bf16 all-gather; transpose = reduce-scatter)."""
    c = _sp_ctx(x.shape)
    if c is None:
        return x
    ctx, tp, spec = c
    out_spec = jax.sharding.PartitionSpec(spec[0], None, None)

    def body(xl):
        return opt_barrier(
            jax.lax.all_gather(xl, "model", axis=1, tiled=True))

    return bf16_tangent(shard_map_compat(
        body, mesh=ctx.mesh, in_specs=(spec,), out_specs=out_spec)(x))


def sp_col_projects(x: jax.Array, ws: tuple, features: tuple):
    """Fused SP-entry + column-parallel projections.

    x [B, S(seq-sharded), D]; each w [D, F_i] column-sharded over model when
    features[i] names a sharded logical axis (None -> replicated output).
    One all-gather serves every projection, and — the point — the backward
    pass emits ONE bf16 psum_scatter for the summed dx instead of GSPMD's
    fp32 all-reduce tuple (measured 1.0 TB of the deepseek-coder train
    cell's 1.7 TB all-reduce traffic)."""
    c = _sp_ctx((x.shape[0], x.shape[1], x.shape[2]))
    if c is None:
        outs = []
        for w, f in zip(ws, features):
            h = x @ w
            if f:
                h = constrain(h, ("act_batch", "act_seq", f))
            outs.append(h)
        return tuple(outs)
    ctx, tp, res_spec = c
    PS = jax.sharding.PartitionSpec
    w_specs = tuple(PS(None, "model" if f else None) for f in features)
    out_specs = tuple(
        ctx.resolve(("act_batch", None, f), (x.shape[0], x.shape[1], w.shape[1]))
        for w, f in zip(ws, features))
    def body(xl, *wl):
        xf = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
        # barrier: stops XLA:CPU's bf16->f32 dot-operand promotion from
        # hoisting the convert above the gather (which would double the
        # wire bytes; TPU has native bf16 dots and no such promotion)
        xf = opt_barrier(xf)
        return tuple(xf @ w for w in wl)

    outs = shard_map_compat(body, mesh=ctx.mesh,
                            in_specs=(res_spec,) + w_specs,
                            out_specs=out_specs)(x, *ws)
    return tuple(bf16_tangent(o) for o in outs)


def rs_project(h: jax.Array, w: jax.Array, feature: str) -> jax.Array:
    """Row-parallel projection with fused reduce-scatter: h [B, S, F]
    (F sharded over model as `feature`), w [F, D] -> [B, S(seq-sharded), D].
    psum_scatter replaces GSPMD's all-reduce(+later slice): half the wire
    bytes before even counting the fp32->bf16 saving."""
    c = _sp_ctx((h.shape[0], h.shape[1], w.shape[-1]))
    if c is None:
        from repro.parallel.ctx import constrain as _cons

        return _cons(h @ w, ("act_batch", "act_res", None))
    ctx, tp, out_spec = c
    h_spec = ctx.resolve(("act_batch", None, feature), h.shape)
    if h_spec[2] != "model" or h.shape[1] % tp:
        from repro.parallel.ctx import constrain as _cons

        return _cons(h @ w, ("act_batch", "act_res", None))
    w_spec = jax.sharding.PartitionSpec("model", None)

    def body(hl, wl):
        part = opt_barrier(hl @ wl)
        return jax.lax.psum_scatter(part.astype(hl.dtype), "model",
                                    scatter_dimension=1, tiled=True)

    return bf16_tangent(shard_map_compat(
        body, mesh=ctx.mesh, in_specs=(h_spec, w_spec),
        out_specs=out_spec)(h, w))
