"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD: within a chunk the token-mixing is a masked quadratic form
(MXU-friendly); across chunks a linear state recurrence carries
[B, H, P, N] states via lax.scan.  All einsums are local per head shard —
the only collectives an SSM layer should emit are FSDP weight gathers,
which is exactly what the HLO inspector asserts for the ssm family.

Weight layout note: Mamba2 fuses z/xBC/dt into one in_proj; we keep three
projections with identical total parameter count so each output dim shards
cleanly over the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.models.layers import rmsnorm
from repro.parallel.ctx import constrain


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def ssm_specs(cfg: ModelConfig, layers: int | None) -> dict:
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_ssm_heads
    cc = conv_channels(cfg)
    lyr = (layers,) if layers is not None else ()
    lax_ = ("layers",) if layers is not None else ()

    def spec(shape, axes, **kw):
        return P.ParamSpec(lyr + shape, lax_ + axes, **kw)

    return {
        "wz": P.dense(d, di, "embed", "ssm_inner", layers),
        "wxbc": P.dense(d, cc, "embed", "ssm_inner", layers),
        "wdt": P.dense(d, h, "embed", "ssm_heads", layers),
        "conv_w": spec((cfg.conv_width, cc), (None, "ssm_inner")),
        "conv_b": spec((cc,), ("ssm_inner",), init="zeros"),
        "a_log": spec((h,), ("ssm_heads",), dtype=jnp.float32, init="zeros"),
        "d_skip": spec((h,), ("ssm_heads",), dtype=jnp.float32, init="ones"),
        "dt_bias": spec((h,), ("ssm_heads",), dtype=jnp.float32, init="zeros"),
        "norm": P.scale(di, layers),
        "out": P.dense(di, d, "ssm_inner", "embed", layers),
    }


def causal_conv(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """Depthwise causal conv via tap shifts.  x: [B,S,C]; w: [W,C]."""
    width = w.shape[0]
    out = x * w[-1] + b
    for k in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[-1 - k]
    return out


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
                c_in: jax.Array, chunk: int,
                init_state: jax.Array | None = None):
    """Chunked SSD scan.

    x: [B,S,H,P]  dt: [B,S,H] (post-softplus)  a: [H] (negative)
    b_in/c_in: [B,S,G,N].  Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)
    hg = h // g  # heads per group

    f32 = jnp.float32
    dtc = dt.reshape(bsz, nc, chunk, h).astype(f32)
    da = dtc * a  # [B,c,Q,H]
    seg = jnp.cumsum(da, axis=2)
    xc = x.reshape(bsz, nc, chunk, h, p)
    bc = b_in.reshape(bsz, nc, chunk, g, n)
    cc = c_in.reshape(bsz, nc, chunk, g, n)

    # --- intra-chunk (quadratic, masked) ---
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", cc.astype(f32), bc.astype(f32))
    cb = jnp.repeat(cb, hg, axis=2)  # [B,c,H,Q,K]
    seg_t = seg.swapaxes(2, 3)  # [B,c,H,Q]
    decay = jnp.exp(seg_t[..., :, None] - seg_t[..., None, :])  # [B,c,H,Q,K]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = jnp.where(mask[None, None, None], cb * decay, 0.0)
    dt_k = dtc.swapaxes(2, 3)[..., None, :]  # [B,c,H,1,K]
    m = m * dt_k
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", m, xc.astype(f32))

    # --- chunk states ---
    last = seg[:, :, -1:, :]  # [B,c,1,H]
    w_k = jnp.exp(last - seg) * dtc  # decay from k to chunk end × dt_k
    bh_ = jnp.repeat(bc.astype(f32), hg, axis=3)  # [B,c,K,H,N] (group->head)
    states = jnp.einsum("bckhn,bckh,bckhp->bchpn",
                        bh_, w_k, xc.astype(f32))

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(last[:, :, 0, :])  # [B,c,H]
    s0 = (jnp.zeros((bsz, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st_local, dec = inp
        new = carry * dec[:, :, None, None] + st_local
        return new, carry  # emit the *incoming* state for this chunk

    final, prev_states = jax.lax.scan(
        step, s0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)  # [B,c,H,P,N]

    ch = jnp.repeat(cc.astype(f32), hg, axis=3)  # [B,c,Q,H,N]
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         ch, jnp.exp(seg), prev_states)
    y = (y_intra + y_inter).reshape(bsz, s, h, p).astype(x.dtype)
    return y, final


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    a: jax.Array, b_in: jax.Array, c_in: jax.Array):
    """One-token SSD update.  state [B,H,P,N], x [B,H,P], dt [B,H],
    b_in/c_in [B,G,N].  Returns (y [B,H,P], new_state)."""
    f32 = jnp.float32
    h = x.shape[1]
    g = b_in.shape[1]
    hg = h // g
    bh = jnp.repeat(b_in, hg, axis=1).astype(f32)   # [B,H,N]
    ch = jnp.repeat(c_in, hg, axis=1).astype(f32)
    dtf = dt.astype(f32)
    da = jnp.exp(dtf * a)                            # [B,H]
    upd = (dtf[..., None] * x.astype(f32))[..., None] * bh[:, :, None, :]
    new_state = state * da[..., None, None] + upd    # [B,H,P,N]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch).astype(x.dtype)
    return y, new_state


def mamba_block(cfg: ModelConfig, p: dict, x: jax.Array, *,
                use_pallas: bool = False, return_state: bool = False):
    """Full-sequence Mamba2 block.  x: [B,S,D] -> y [B,S,D].
    With ``return_state``: (y, (conv_tail [B,W-1,CC], ssm_state [B,H,P,N]))."""
    bsz, s, _ = x.shape
    di, h, n, g = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_groups
    hd = cfg.ssm_head_dim

    z = x @ p["wz"]
    z = constrain(z, ("act_batch", "act_seq", "act_inner"))
    xbc_pre = x @ p["wxbc"]
    xbc_pre = constrain(xbc_pre, ("act_batch", "act_seq", "act_inner"))
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])

    xbc = jax.nn.silu(causal_conv(p["conv_w"], p["conv_b"], xbc_pre))
    xc = xbc[..., :di].reshape(bsz, s, h, hd)
    b_in = xbc[..., di:di + g * n].reshape(bsz, s, g, n)
    c_in = xbc[..., di + g * n:].reshape(bsz, s, g, n)
    a = -jnp.exp(p["a_log"])

    if use_pallas:
        from repro.kernels import ops as kops
        y, final = kops.ssd_scan(xc, dt, a, b_in, c_in, cfg.ssd_chunk)
    else:
        y, final = ssd_chunked(xc, dt, a, b_in, c_in, min(cfg.ssd_chunk, s))
    y = y + xc * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, s, di) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    y = constrain(y, ("act_batch", "act_seq", "act_inner"))
    out = y @ p["out"]
    if return_state:
        w = cfg.conv_width
        return out, (xbc_pre[:, s - (w - 1):, :], final)
    return out


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                 conv_state: jax.Array, ssm_state: jax.Array):
    """One-token Mamba2 step.  x: [B,1,D]; conv_state [B,W-1,CC];
    ssm_state [B,H,P,N].  Returns (y [B,1,D], conv_state, ssm_state)."""
    bsz = x.shape[0]
    di, h, n, g = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_groups
    hd = cfg.ssm_head_dim
    x1 = x[:, 0]

    z = x1 @ p["wz"]
    xbc = x1 @ p["wxbc"]
    dt = jax.nn.softplus((x1 @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])

    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # [B,W,CC]
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv)
    new_conv_state = window[:, 1:]

    xc = xbc[..., :di].reshape(bsz, h, hd)
    b_in = xbc[..., di:di + g * n].reshape(bsz, g, n)
    c_in = xbc[..., di + g * n:].reshape(bsz, g, n)
    a = -jnp.exp(p["a_log"])

    y, new_ssm = ssd_decode_step(ssm_state, xc, dt, a, b_in, c_in)
    y = y + xc * p["d_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(bsz, di) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return (y @ p["out"])[:, None, :], new_conv_state, new_ssm


# ======================================================================
# Context-parallel SSD (sequence-sharded Mamba2 block)
#
# Under the train/prefill rule sets the residual stream is sequence-
# sharded over `model`.  Left to GSPMD, the inter-chunk state recurrence
# (a lax.scan whose xs are chunk-sharded) forces replication of the whole
# [B, n_chunks, H, P, N] state tensor — measured 640 MiB all-reduce +
# all-gather PER LAYER on mamba2-2.7b prefill_32k (the dominant roofline
# term, 25x over compute).  This shard_map implementation keeps everything
# sequence-local and exchanges only:
#   * a (W-1)-token halo for the causal conv   (collective-permute, ~KBs)
#   * one [tp, B, H, P, N] state summary       (all-gather, ~5 MB/shard)
#   * the replicated weights                   (the usual FSDP/TP gathers)
# The cross-shard prefix is exact: the SSD recurrence is linear in its
# initial state, so each shard runs zero-init locally and adds the decayed
# incoming prefix state afterwards.
# ======================================================================


def _cp_prefix(s_all: jax.Array, d_all: jax.Array, my_idx: jax.Array):
    """Incoming prefix state for this shard.
    s_all: [tp,B,H,P,N] zero-init final states; d_all: [tp,B,H] total decays.
    prefix_i = sum_{j<i} s_j * prod_{j<k<i} d_k  (linear-recurrence prefix)."""
    tp = s_all.shape[0]
    acc = jnp.zeros_like(s_all[0])
    incoming = []
    for j in range(tp):
        incoming.append(acc)
        acc = acc * d_all[j][..., None, None] + s_all[j]
    stacked = jnp.stack(incoming)              # [tp,B,H,P,N]
    return (jax.lax.dynamic_index_in_dim(stacked, my_idx, 0, keepdims=False),
            acc)


def _mamba_cp_body(cfg: ModelConfig, axis: str, tp: int, return_state: bool,
                   p: dict, x: jax.Array):
    """Per-shard body.  x: [b_loc, s_loc, D] (seq-sharded over `axis`)."""
    bsz, s_loc, _ = x.shape
    di, h, n, g = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_groups
    hd = cfg.ssm_head_dim
    w = cfg.conv_width
    idx = jax.lax.axis_index(axis)

    z = x @ p["wz"]
    xbc_pre = x @ p["wxbc"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])

    # --- causal conv with left halo from the previous shard ---
    tail = xbc_pre[:, s_loc - (w - 1):, :]
    halo = jax.lax.ppermute(tail, axis,
                            [(i, i + 1) for i in range(tp - 1)])
    full = jnp.concatenate([halo, xbc_pre], axis=1)  # [b, s_loc+w-1, CC]
    conv = jnp.zeros_like(xbc_pre) + p["conv_b"]
    for k in range(w):
        conv = conv + full[:, k:k + s_loc, :] * p["conv_w"][k]
    xbc = jax.nn.silu(conv)

    xc = xbc[..., :di].reshape(bsz, s_loc, h, hd)
    b_in = xbc[..., di:di + g * n].reshape(bsz, s_loc, g, n)
    c_in = xbc[..., di + g * n:].reshape(bsz, s_loc, g, n)
    a = -jnp.exp(p["a_log"])

    # --- local zero-init SSD + cross-shard prefix correction ---
    y0, s_local = ssd_chunked(xc, dt, a, b_in, c_in,
                              min(cfg.ssd_chunk, s_loc))
    da = (dt * a)                                      # [b, s_loc, h]
    total_decay = jnp.exp(jnp.sum(da, axis=1))         # [b, h]
    s_all = jax.lax.all_gather(s_local, axis)          # [tp,b,h,p,n]
    d_all = jax.lax.all_gather(total_decay, axis)      # [tp,b,h]
    s_in, s_global = _cp_prefix(s_all, d_all, idx)

    decay_t = jnp.exp(jnp.cumsum(da, axis=1))          # [b, s_loc, h]
    hg = h // g
    c_h = jnp.repeat(c_in.astype(jnp.float32), hg, axis=2)  # [b,s,h,n]
    y_corr = jnp.einsum("bshn,bsh,bhpn->bshp", c_h, decay_t, s_in)
    y = y0 + y_corr.astype(y0.dtype)

    y = y + xc * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, s_loc, di) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = y @ p["out"]

    if not return_state:
        return out
    # global conv tail (last shard's) + exact global final state, both
    # computed replicated so the out_spec can declare them unsharded.
    tail_all = jax.lax.all_gather(full[:, -(w - 1):, :], axis)  # [tp,b,w-1,CC]
    conv_tail = tail_all[tp - 1]
    return out, (conv_tail, s_global)


def mamba_block_cp(cfg: ModelConfig, p: dict, x: jax.Array, *,
                   use_pallas: bool = False, return_state: bool = False):
    """Context-parallel Mamba2 block via shard_map (sequence sharded over
    the model axis).  Falls back to the GSPMD path when inapplicable."""
    from functools import partial

    from repro.parallel.ctx import _current

    ctx = _current()
    tp = ctx.axis_sizes.get("model", 1) if ctx else 1
    s = x.shape[1]
    applicable = (
        ctx is not None and tp > 1
        and ctx.rules.get("act_res") == "model"
        and s % tp == 0 and (s // tp) % min(cfg.ssd_chunk, s // tp) == 0)
    if not applicable:
        return mamba_block(cfg, p, x, use_pallas=use_pallas,
                           return_state=return_state)

    mesh = ctx.mesh
    x_spec = ctx.resolve(("act_batch", "act_res", None), x.shape)
    p_specs = jax.tree.map(lambda _: jax.sharding.PartitionSpec(), p)
    body = partial(_mamba_cp_body, cfg, "model", tp, return_state)
    if return_state:
        b_ax = x_spec[0]
        out_specs = (x_spec,
                     (jax.sharding.PartitionSpec(b_ax, None, None),
                      jax.sharding.PartitionSpec(b_ax, None, None, None)))
    else:
        out_specs = x_spec
    from repro.parallel.ctx import shard_map_compat
    fn = shard_map_compat(body, mesh=mesh, in_specs=(p_specs, x_spec),
                          out_specs=out_specs)
    return fn(p, x)
