"""Per-family block stacks: spec declaration + forward/prefill/decode.

Every family lowers through ``jax.lax.scan`` over stacked layer parameters so
the HLO stays one-layer-sized regardless of depth — essential both for
compile time on the 512-device dry-run and for XLA's collective scheduling
(one FSDP gather per scan step, overlappable).

Families:
  dense   — [attn + SwiGLU] × L
  moe     — [attn + MoE] × L
  ssm     — [Mamba2] × L
  hybrid  — ([Mamba2] × (attn_every-1) + shared-attn block) × groups  (zamba2)
  vlm     — ([gated cross-attn] + [self] × cross_every) × groups      (llama-3.2-v)
  encdec  — encoder [bidir attn + GELU MLP] × Le; decoder [self + cross + MLP] × Ld
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.models.attention import (attn_specs, decode_attention,
                                    full_attention, tp_size)
from repro.models.layers import (embed_specs, embed_tokens, gelu_mlp,
                                 gelu_mlp_specs, head_geom, logits_from,
                                 rmsnorm, rmsnorm_spec, sinusoidal_positions,
                                 swiglu, swiglu_specs)
from repro.models.moe import moe_ffn, moe_specs
from repro.models.ssm import (conv_channels, mamba_block, mamba_decode,
                              ssm_specs)
from repro.parallel.ctx import constrain


# ===================================================================== specs


def _dense_layer_specs(cfg: ModelConfig, n: int) -> dict:
    return {
        "ln1": rmsnorm_spec(cfg.d_model, n),
        "attn": attn_specs(cfg, n),
        "ln2": rmsnorm_spec(cfg.d_model, n),
        "mlp": swiglu_specs(cfg, n),
    }


def _moe_layer_specs(cfg: ModelConfig, n: int) -> dict:
    return {
        "ln1": rmsnorm_spec(cfg.d_model, n),
        "attn": attn_specs(cfg, n),
        "ln2": rmsnorm_spec(cfg.d_model, n),
        "moe": moe_specs(cfg, n),
    }


def _ssm_layer_specs(cfg: ModelConfig, n: int) -> dict:
    return {"ln": rmsnorm_spec(cfg.d_model, n), "mamba": ssm_specs(cfg, n)}


def _shared_attn_specs(cfg: ModelConfig) -> dict:
    """zamba2's globally shared attention+MLP block (unstacked)."""
    return {
        "attn": attn_specs(cfg, None),
        "mlp": swiglu_specs(cfg, None),
        "ln_attn": rmsnorm_spec(cfg.d_model, None),
        "ln_mlp": rmsnorm_spec(cfg.d_model, None),
    }


def _cross_layer_specs(cfg: ModelConfig, n: int) -> dict:
    return {
        "ln": rmsnorm_spec(cfg.d_model, n),
        "attn": attn_specs(cfg, n),
        "gate_attn": P.ParamSpec((n, 1), ("layers", None), jnp.float32, "zeros"),
        "ln_mlp": rmsnorm_spec(cfg.d_model, n),
        "mlp": swiglu_specs(cfg, n),
        "gate_mlp": P.ParamSpec((n, 1), ("layers", None), jnp.float32, "zeros"),
    }


def _encdec_dec_layer_specs(cfg: ModelConfig, n: int) -> dict:
    return {
        "ln1": rmsnorm_spec(cfg.d_model, n),
        "self": attn_specs(cfg, n),
        "ln2": rmsnorm_spec(cfg.d_model, n),
        "cross": attn_specs(cfg, n),
        "ln3": rmsnorm_spec(cfg.d_model, n),
        "mlp": gelu_mlp_specs(cfg, n),
    }


def param_specs(cfg: ModelConfig) -> dict:
    fam = cfg.family
    specs: dict[str, Any] = {"embed": embed_specs(cfg),
                             "final_norm": rmsnorm_spec(cfg.d_model)}
    if fam == "dense":
        specs["layers"] = _dense_layer_specs(cfg, cfg.n_layers)
    elif fam == "moe":
        specs["layers"] = _moe_layer_specs(cfg, cfg.n_layers)
    elif fam == "ssm":
        specs["layers"] = _ssm_layer_specs(cfg, cfg.n_layers)
    elif fam == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        n_mamba = groups * (cfg.attn_every - 1)
        specs["layers"] = _ssm_layer_specs(cfg, n_mamba)
        specs["shared"] = _shared_attn_specs(cfg)
        specs["site_norm"] = rmsnorm_spec(cfg.d_model, groups)
    elif fam == "vlm":
        groups = cfg.n_layers // cfg.cross_every
        specs["layers"] = _dense_layer_specs(cfg, cfg.n_layers)
        specs["cross"] = _cross_layer_specs(cfg, groups)
    elif fam == "encdec":
        specs["enc_layers"] = {
            "ln1": rmsnorm_spec(cfg.d_model, cfg.n_encoder_layers),
            "attn": attn_specs(cfg, cfg.n_encoder_layers),
            "ln2": rmsnorm_spec(cfg.d_model, cfg.n_encoder_layers),
            "mlp": gelu_mlp_specs(cfg, cfg.n_encoder_layers),
        }
        specs["enc_norm"] = rmsnorm_spec(cfg.d_model)
        specs["layers"] = _encdec_dec_layer_specs(cfg, cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam}")
    return specs


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    total = P.count(param_specs(cfg))
    if active_only and cfg.n_experts and cfg.top_k:
        expert = 3 * cfg.d_model * cfg.d_ff  # gate+up+down per expert
        total -= cfg.n_layers * expert * (cfg.n_experts - cfg.top_k)
    return total


def nonembedding_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    n = param_count(cfg, active_only)
    emb = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return n - emb


# ================================================================= forward


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(f"unknown remat mode {mode}")


from repro.models.layers import bf16_tangent as _bf16_tangent


def _res(x):
    """Residual-stream boundary: (1) sharding constraint — under the
    train/prefill rule sets this is Megatron sequence parallelism (saved
    per-layer activations shard over the model axis; GSPMD inserts the
    block-boundary all-gather/reduce-scatter); (2) cotangent dtype pin —
    without it the f32 cotangents from the loss head propagate through the
    whole backward residual chain, and XLA materializes an f32 copy of the
    entire saved-activation stack (measured: +2× activation memory and 2×
    collective payloads on deepseek-coder-33b)."""
    return _bf16_tangent(constrain(x, ("act_batch", "act_res", None)))


def _dense_block(cfg, p, x, pos0=0, use_pallas=False):
    h = x + full_attention(cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                           pos0=pos0, use_pallas=use_pallas)
    return _res(h + swiglu(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps)))


def _moe_block(cfg, p, x, pos0=0):
    h = x + full_attention(cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                           pos0=pos0)
    y, aux = moe_ffn(cfg, p["moe"], rmsnorm(p["ln2"], h, cfg.norm_eps))
    return _res(h + y), jnp.mean(aux)


def _ssm_block(cfg, p, x, use_pallas=False):
    from repro.models.ssm import mamba_block_cp
    return _res(x + mamba_block_cp(cfg, p["mamba"],
                                   rmsnorm(p["ln"], x, cfg.norm_eps),
                                   use_pallas=use_pallas))


def _shared_block(cfg, p, site_norm, x, pos0=0):
    h = x + full_attention(
        cfg, p["attn"],
        rmsnorm(site_norm, rmsnorm(p["ln_attn"], x, cfg.norm_eps), cfg.norm_eps),
        pos0=pos0)
    return _res(h + swiglu(p["mlp"], rmsnorm(p["ln_mlp"], h, cfg.norm_eps)))


def _cross_block(cfg, p, x, ctx_kv):
    # image/patch context is replicated, not sequence-sharded: no kv gather
    h = full_attention(cfg, p["attn"], rmsnorm(p["ln"], x, cfg.norm_eps),
                       kv_x=ctx_kv, causal=False, gather_kv=False)
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
    m = swiglu(p["mlp"], rmsnorm(p["ln_mlp"], x, cfg.norm_eps))
    return _res(x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * m)


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: str = "none", use_pallas: bool = False) -> tuple[jax.Array, dict]:
    """Full-sequence forward -> (logits [B,S,Vpad], metrics)."""
    fam = cfg.family
    metrics: dict[str, jax.Array] = {}

    if fam == "encdec":
        return _encdec_forward(cfg, params, batch, remat)

    x = _res(embed_tokens(params["embed"], batch["tokens"]))

    if fam in ("dense",):
        body = _remat(
            lambda x, p: (_dense_block(cfg, p, x, use_pallas=use_pallas),
                          None), remat)
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif fam == "moe":
        def moe_body(x, p):
            y, aux = _moe_block(cfg, p, x)
            return y, aux
        body = _remat(moe_body, remat)
        x, auxes = jax.lax.scan(body, x, params["layers"])
        metrics["moe_aux"] = jnp.mean(auxes)
    elif fam == "ssm":
        body = _remat(lambda x, p: (_ssm_block(cfg, p, x, use_pallas), None), remat)
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif fam == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        per = cfg.attn_every - 1
        shared = params["shared"]
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])

        def group_body(x, gp):
            layer_p, site_norm = gp
            inner = _remat(
                lambda x, p: (_ssm_block(cfg, p, x, use_pallas), None), remat)
            x, _ = jax.lax.scan(inner, x, layer_p)
            x = _remat(
                lambda x, sn: (_shared_block(cfg, shared, sn, x), None), remat
            )(x, site_norm)[0]
            return x, None

        x, _ = jax.lax.scan(group_body, x, (stacked, params["site_norm"]))
    elif fam == "vlm":
        groups = cfg.n_layers // cfg.cross_every
        per = cfg.cross_every
        img = constrain(batch["image_embed"], ("act_batch", None, None))
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])

        def group_body(x, gp):
            cross_p, layer_p = gp
            x = _remat(lambda x, cp: (_cross_block(cfg, cp, x, img), None),
                       remat)(x, cross_p)[0]
            inner = _remat(lambda x, p: (_dense_block(cfg, p, x), None), remat)
            x, _ = jax.lax.scan(inner, x, layer_p)
            return x, None

        x, _ = jax.lax.scan(group_body, x, (params["cross"], stacked))
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_from(params["embed"], cfg, x), metrics


def _encoder(cfg: ModelConfig, params: dict, audio_embed: jax.Array,
             remat: str) -> jax.Array:
    x = audio_embed + sinusoidal_positions(audio_embed.shape[1], cfg.d_model)
    x = _res(x)

    def body(x, p):
        h = x + full_attention(cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                               causal=False)
        return _res(h + gelu_mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps))), None

    x, _ = jax.lax.scan(_remat(body, remat), x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _encdec_forward(cfg: ModelConfig, params: dict, batch: dict, remat: str):
    enc = _encoder(cfg, params, batch["audio_embed"], remat)
    x = embed_tokens(params["embed"], batch["tokens"])
    x = _res(x + sinusoidal_positions(x.shape[1], cfg.d_model))

    def body(x, p):
        h = x + full_attention(cfg, p["self"], rmsnorm(p["ln1"], x, cfg.norm_eps))
        h = h + full_attention(cfg, p["cross"], rmsnorm(p["ln2"], h, cfg.norm_eps),
                               kv_x=enc, causal=False)
        return _res(h + gelu_mlp(p["mlp"], rmsnorm(p["ln3"], h, cfg.norm_eps))), None

    x, _ = jax.lax.scan(_remat(body, remat), x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_from(params["embed"], cfg, x), {}
