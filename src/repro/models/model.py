"""Public model API: one object per architecture config.

All methods are pure functions of (params, batch) so they can be jitted,
lowered abstractly for the dry-run, or wrapped in shard_map-free smoke
tests identically.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decode as D
from repro.models import params as P
from repro.models import stack
from repro.models.layers import cross_entropy


class Model:
    def __init__(self, cfg: ModelConfig, use_pallas: bool = False):
        self.cfg = cfg
        self.use_pallas = use_pallas

    # ---- parameters ----
    def param_specs(self):
        return stack.param_specs(self.cfg)

    def abstract_params(self):
        return P.abstract(self.param_specs())

    def init_params(self, key: jax.Array):
        return P.initialize(self.param_specs(), key)

    # ---- training ----
    def loss(self, params: dict, batch: dict, *, remat: str = "none",
             z_loss: float = 0.0):
        logits, metrics = stack.forward(self.cfg, params, batch, remat=remat,
                                        use_pallas=self.use_pallas)
        loss, aux = cross_entropy(logits, batch["labels"],
                                  self.cfg.vocab_size, z_loss)
        metrics.update(aux)
        if "moe_aux" in metrics:
            loss = loss + self.cfg.router_aux_weight * metrics["moe_aux"]
        return loss, metrics

    # ---- serving ----
    def prefill(self, params: dict, batch: dict, cache_len: int | None = None):
        return D.prefill(self.cfg, params, batch, cache_len)

    def decode_step(self, params: dict, cache: dict, token: jax.Array,
                    pos: jax.Array):
        return D.decode_step(self.cfg, params, cache, token, pos)

    def decode_chunk(self, params: dict, cache: dict, tokens: jax.Array,
                     pos: jax.Array, n_new: jax.Array):
        return D.decode_chunk(self.cfg, params, cache, tokens, pos, n_new)

    def decode_greedy_step(self, params: dict, cache: dict, token: jax.Array,
                           pos: jax.Array):
        """One-token decode with argmax fused into the jitted program:
        returns (tokens [B] int32, new cache).  The all-greedy serving
        fast path — only the selected token vector crosses to the host,
        and none of the sampling pipeline (sort/softmax/cumsum) lowers."""
        logits, cache = D.decode_step(self.cfg, params, cache, token, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def decode_greedy_chunk(self, params: dict, cache: dict,
                            tokens: jax.Array, pos: jax.Array,
                            n_new: jax.Array):
        """Chunked decode with fused argmax (paged engine, all-greedy)."""
        logits, cache = D.decode_chunk(self.cfg, params, cache, tokens, pos,
                                       n_new)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def decode_sample_step(self, params: dict, cache: dict, token: jax.Array,
                           pos: jax.Array, lane: dict):
        """One-token decode with sampling fused into the jitted program:
        returns (tokens [B] int32, new cache).  ``lane`` is the per-slot
        sampling state (serve.api.LaneState.as_args()); greedy lanes
        (temperature 0) still get exact argmax."""
        logits, cache = D.decode_step(self.cfg, params, cache, token, pos)
        return D.sample_from_logits(logits, lane), cache

    def decode_sample_chunk(self, params: dict, cache: dict,
                            tokens: jax.Array, pos: jax.Array,
                            n_new: jax.Array, lane: dict):
        """Chunked decode with fused sampling (the paged engine's step)."""
        logits, cache = D.decode_chunk(self.cfg, params, cache, tokens, pos,
                                       n_new)
        return D.sample_from_logits(logits, lane), cache

    def decode_paged_chunk(self, params: dict, cache: dict,
                           tokens: jax.Array, pos: jax.Array,
                           n_new: jax.Array, page_table: jax.Array):
        return D.decode_paged_chunk(self.cfg, params, cache, tokens, pos,
                                    n_new, page_table)

    def decode_paged_greedy_chunk(self, params: dict, cache: dict,
                                  tokens: jax.Array, pos: jax.Array,
                                  n_new: jax.Array, page_table: jax.Array):
        """Chunked decode over the paged KV pool with fused argmax — the
        kernel-enabled paged engine's all-greedy step.  KV reads and
        writes both go through the page table; no dense working cache."""
        logits, cache = D.decode_paged_chunk(self.cfg, params, cache, tokens,
                                             pos, n_new, page_table)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def decode_paged_sample_chunk(self, params: dict, cache: dict,
                                  tokens: jax.Array, pos: jax.Array,
                                  n_new: jax.Array, page_table: jax.Array,
                                  lane: dict):
        """Chunked paged decode with fused sampling."""
        logits, cache = D.decode_paged_chunk(self.cfg, params, cache, tokens,
                                             pos, n_new, page_table)
        return D.sample_from_logits(logits, lane), cache

    def cache_specs(self, batch: int, seq_len: int):
        return D.cache_specs(self.cfg, batch, seq_len)

    def paged_cache_specs(self, num_blocks: int, block_size: int):
        return D.paged_cache_specs(self.cfg, num_blocks, block_size)

    def abstract_cache(self, batch: int, seq_len: int):
        return P.abstract(self.cache_specs(batch, seq_len))

    def zero_cache(self, batch: int, seq_len: int):
        return P.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                          self.cache_specs(batch, seq_len))

    # ---- batch/input declaration (dry-run ShapeDtypeStructs) ----
    def input_specs(self, shape: ShapeConfig) -> dict[str, Any]:
        """Abstract inputs for one assignment cell.  Modality frontends are
        stubs per the assignment: precomputed frame/patch embeddings."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32, bf16 = jnp.int32, jnp.bfloat16

        def tok(n):
            return jax.ShapeDtypeStruct((b, n), i32)

        if shape.kind == "train":
            if cfg.family == "encdec":
                return {
                    "audio_embed": jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16),
                    "tokens": tok(cfg.decoder_train_len),
                    "labels": tok(cfg.decoder_train_len),
                }
            batch: dict[str, Any] = {"tokens": tok(s), "labels": tok(s)}
            if cfg.family == "vlm":
                batch["image_embed"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_image_tokens, cfg.d_model), bf16)
            return batch
        if shape.kind == "prefill":
            if cfg.family == "encdec":
                return {
                    "audio_embed": jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16),
                    "tokens": tok(cfg.decoder_train_len),
                }
            batch = {"tokens": tok(s)}
            if cfg.family == "vlm":
                batch["image_embed"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_image_tokens, cfg.d_model), bf16)
            return batch
        # decode: one token against a seq_len-deep cache
        return {
            "token": tok(1),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }

    def sample_batch(self, shape: ShapeConfig, key: jax.Array) -> dict[str, Any]:
        """Materialized random batch matching input_specs (smoke/real runs)."""
        specs = self.input_specs(shape)
        out = {}
        for name, sds in specs.items():
            key, sub = jax.random.split(key)
            if sds.dtype == jnp.int32 and name in ("tokens", "labels", "token"):
                out[name] = jax.random.randint(sub, sds.shape, 0,
                                               self.cfg.vocab_size, jnp.int32)
            elif name == "pos":
                out[name] = jnp.zeros(sds.shape, jnp.int32)
            else:
                out[name] = jax.random.normal(sub, sds.shape, jnp.float32
                                              ).astype(sds.dtype)
        return out


def build(cfg: ModelConfig, use_pallas: bool = False) -> Model:
    return Model(cfg, use_pallas)
