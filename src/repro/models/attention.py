"""GQA attention: train/prefill (chunked, kv-replicated) and decode
(kv-padded sharded cache) paths, plus cross-attention.

Sharding strategy (DESIGN.md §5): projections are TP-sharded on their
flattened head output dims (always divisible); per-head activation layouts
are reached by reshape so GSPMD propagates the tiling even when neither the
kv nor the group dim alone divides the model axis.  The q group dim is
zero-padded to ``g_pad`` (HeadGeom) so the flattened run layout divides tp;
decode caches zero-pad the kv dim itself to ``kv_pad`` so the cache shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.models.layers import (HeadGeom, head_geom, pad_group_dim,
                                 rmsnorm, rope, rs_project, sp_col_projects,
                                 sp_gather_seq)
from repro.parallel.ctx import _current, constrain

NEG_INF = -1e9


def tp_size() -> int:
    ctx = _current()
    if ctx is None:
        return 1
    return ctx.axis_sizes.get("model", 1)


def attn_specs(cfg: ModelConfig, layers: int | None, *, kv_d: int | None = None) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    specs = {
        "wq": P.dense(d, h * hd, "embed", "heads_out", layers),
        "wk": P.dense(kv_d or d, kv * hd, "embed", "kv_out", layers),
        "wv": P.dense(kv_d or d, kv * hd, "embed", "kv_out", layers),
        "wo": P.dense(h * hd, d, "heads_out", "embed", layers),
    }
    if cfg.qk_norm:
        specs["q_scale"] = P.scale(hd, layers)
        specs["k_scale"] = P.scale(hd, layers)
    return specs


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, kv_x: jax.Array,
                 geom: HeadGeom, q_pos: jax.Array, k_pos: jax.Array | None,
                 self_attn: bool):
    """Returns q [B,Sq,h_run,hd] (flat padded-head layout — no 5-D grouped
    detour, which GSPMD cannot tile cleanly) and k,v [B,Sk,KV,hd].
    Self-attention fuses the SP gather with all three projections (one
    all-gather forward, one bf16 psum_scatter backward)."""
    hd, kv = geom.head_dim, geom.n_kv
    b, sq = x.shape[0], x.shape[1]
    sk = kv_x.shape[1]

    wq = pad_group_dim(p["wq"], geom, axis_is_out=True)
    if self_attn:
        q, k, v = sp_col_projects(x, (wq, p["wk"], p["wv"]),
                                  ("act_heads", None, None))
    else:
        (q,) = sp_col_projects(x, (wq,), ("act_heads",))
        k = kv_x @ p["wk"]
        v = kv_x @ p["wv"]
    q = constrain(q, ("act_batch", "act_seq", "act_heads"))
    q = q.reshape(b, sq, geom.h_run, hd)
    k = k.reshape(b, sk, kv, hd)
    v = v.reshape(b, sk, kv, hd)

    if cfg.qk_norm:
        q = rmsnorm(p["q_scale"], q, cfg.norm_eps)
        k = rmsnorm(p["k_scale"], k, cfg.norm_eps)

    if cfg.rope_theta > 0:
        q = rope(q, q_pos, cfg.rope_theta)
        if k_pos is not None:
            k = rope(k, k_pos, cfg.rope_theta)
    return q, k, v


def _attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None,
            hd: int) -> jax.Array:
    """q [B,Sq,H,hd], k/v [B,Sk,H,hd] (kv pre-repeated to the q-head count)
    -> out [B,Sq,H,hd].  Flat head layout: GSPMD shards the head dim 1-D,
    which avoids the mixed 5-D tilings that trigger involuntary
    rematerialization in the backward pass."""
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (hd ** -0.5)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v)


def full_attention(cfg: ModelConfig, p: dict, x: jax.Array, *,
                   kv_x: jax.Array | None = None, causal: bool = True,
                   pos0: int = 0, chunk: int = 1024,
                   return_kv: bool = False, gather_kv: bool = True,
                   use_pallas: bool = False):
    """Attention over full sequences (train / prefill / encoder / cross).

    kv replicated over tp (transient, small); q-chunked lax.scan keeps the
    fp32 score block [B, heads, chunk, Sk] bounded so the lowered program's
    peak memory stays within HBM even at 32k.
    """
    geom = head_geom(cfg, tp_size())
    hd = geom.head_dim
    b, sq, d = x.shape
    kv_src = x if kv_x is None else (
        sp_gather_seq(kv_x) if gather_kv else kv_x)
    sk = kv_src.shape[1]

    q_pos = pos0 + jnp.arange(sq)
    k_pos = (pos0 + jnp.arange(sk)) if kv_x is None else None
    q, k, v = _project_qkv(cfg, p, x, kv_src, geom, q_pos, k_pos,
                           self_attn=kv_x is None)

    # flat head layout: repeat kv to the (padded) q-head count and shard the
    # head dim.  The repeat is cheap (kv transient, sliced per shard by the
    # constraint) and buys clean 1-D head sharding through the whole block.
    k_r = jnp.repeat(k, geom.g_pad, axis=2)
    v_r = jnp.repeat(v, geom.g_pad, axis=2)
    q = constrain(q, ("act_batch", "act_seq", "act_heads", None))
    k_r = constrain(k_r, ("act_batch", "act_seq", "act_heads", None))
    v_r = constrain(v_r, ("act_batch", "act_seq", "act_heads", None))

    if (use_pallas and kv_x is None and pos0 == 0 and sq == sk
            and tp_size() == 1 and sq % min(128, sq) == 0):
        # Pallas flash path (TPU target; interpret on CPU).  Per-shard
        # only: under TP the jnp path lowers for GSPMD, the kernel runs
        # inside shard_map deployments.
        from repro.kernels import ops as kops
        bq = min(128, sq)
        qf = q.reshape(b, sq, geom.h_run, hd).swapaxes(1, 2) \
              .reshape(b * geom.h_run, sq, hd)
        kf = k_r.swapaxes(1, 2).reshape(b * geom.h_run, sk, hd)
        vf = v_r.swapaxes(1, 2).reshape(b * geom.h_run, sk, hd)
        out = kops.flash_attention(qf, kf, vf, causal=causal,
                                   block_q=bq, block_k=bq)
        out = out.reshape(b, geom.h_run, sq, hd).swapaxes(1, 2)
        out = out.reshape(b, sq, geom.h_run * hd)
        wo = pad_group_dim(p["wo"], geom, axis_is_out=False)
        y = rs_project(out, wo, "act_heads")
        if return_kv:
            return y, (k, v)
        return y

    k_posv = jnp.arange(sk)

    def block(q_blk: jax.Array, q_pos_blk: jax.Array) -> jax.Array:
        mask = None
        if causal:
            mask = (k_posv[None, :] <= q_pos_blk[:, None] - pos0)
            mask = mask[None, None, :, :]  # [1,1,Sq_blk,Sk]
        return _attend(q_blk, k_r, v_r, mask, hd)

    if sq > chunk and sq % chunk == 0:
        nq = sq // chunk
        q_chunks = jnp.moveaxis(q.reshape(b, nq, chunk, geom.h_run, hd), 1, 0)
        pos_chunks = q_pos.reshape(nq, chunk)
        # remat the chunk body: otherwise the scan stacks fp32 score/prob
        # blocks across chunks for backward — O(S²/chunk) bytes per layer.
        chunk_fn = jax.checkpoint(
            lambda qs, ps: block(qs, ps),
            policy=jax.checkpoint_policies.nothing_saveable)
        out = jax.lax.scan(
            lambda _, qs: (None, chunk_fn(qs[0], qs[1])), None,
            (q_chunks, pos_chunks)
        )[1]  # [nq, B, chunk, H_run, hd]
        out = jnp.moveaxis(out, 0, 1)
    else:
        out = block(q, q_pos)

    out = out.reshape(b, sq, geom.h_run * hd)
    out = constrain(out, ("act_batch", "act_seq", "act_heads"))
    wo = pad_group_dim(p["wo"], geom, axis_is_out=False)
    # SP exit: fused psum_scatter instead of GSPMD's all-reduce(+slice)
    y = rs_project(out, wo, "act_heads")
    if return_kv:
        return y, (k, v)
    return y


# ------------------------------------------------------------ decode path
#
# Cache layout is chosen by decode.cache_specs: when the kv-head count
# divides the model axis the cache shards over kv heads (zero collectives
# in the score path); otherwise the cache shards over SEQUENCE — no head
# padding at all, and the only cross-shard traffic is the softmax stats +
# the [B,H,hd]-sized partial-output reduction (tiny next to cache reads).


def decode_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, update_cache: bool = True,
                     k_pos_offset: int = 0):
    """Single-token decode: x [B,1,D]; caches [B,Smax,KV,hd]; pos [B].

    Returns (y [B,1,D], new_k_cache, new_v_cache).  With
    ``update_cache=False`` the caches are used read-only (cross-attention).
    """
    geom = head_geom(cfg, tp_size())
    hd, kv, g = geom.head_dim, geom.n_kv, geom.group
    b = x.shape[0]
    s_max = k_cache.shape[1]

    q = x @ p["wq"]
    q = constrain(q, ("act_batch", None, "act_heads"))
    q = q.reshape(b, 1, kv, g, hd)
    k_new = (x @ p["wk"]).reshape(b, 1, kv, hd)
    v_new = (x @ p["wv"]).reshape(b, 1, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_scale"], q, cfg.norm_eps)
        k_new = rmsnorm(p["k_scale"], k_new, cfg.norm_eps)
    if cfg.rope_theta > 0:
        posb = pos[:, None]  # [B,1]
        qf = q.reshape(b, 1, kv * g, hd)
        q = rope(qf, posb, cfg.rope_theta).reshape(b, 1, kv, g, hd)
        k_new = rope(k_new, posb, cfg.rope_theta)

    if update_cache:
        k_cache = k_cache.at[jnp.arange(b), pos].set(k_new[:, 0])
        v_cache = v_cache.at[jnp.arange(b), pos].set(v_new[:, 0])
        valid = jnp.arange(s_max)[None, :] <= pos[:, None]
    else:
        valid = jnp.arange(s_max)[None, :] >= k_pos_offset  # all-valid window

    q4 = q[:, 0]  # [B,KV,G,hd]
    scores = jnp.einsum("bkgh,bskh->bkgs", q4, k_cache,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    # fp32 softmax weights, one rounding after the PV product: the same
    # accumulation discipline as the Pallas paged kernel, so every decode
    # pathway (single-token, chunked, paged) rounds at the same points
    # and token streams stay bit-comparable across engines
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs,
                     v_cache.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(b, 1, kv * g * hd)
    out = constrain(out, ("act_batch", None, "act_heads"))
    y = out @ p["wo"]
    y = constrain(y, ("act_batch", None, None))
    return y, k_cache, v_cache


def chunk_decode_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                           k_cache: jax.Array, v_cache: jax.Array,
                           pos: jax.Array, n_new: jax.Array):
    """Multi-token decode against the cache (chunked prefill / decode mix).

    x [B,C,D]; caches [B,Smax,KV,hd]; pos [B] is each lane's first write
    position; n_new [B] in [0, C] is how many of the lane's C tokens are
    real.  Rows beyond ``n_new`` are neither written to the cache nor
    attended by valid queries — their outputs are garbage the caller
    discards (the engine samples only from position ``n_new - 1``).

    Query i of a lane attends cache positions j <= pos + i, so a chunk is
    causally exact against both the pre-existing cache and itself.
    Returns (y [B,C,D], new_k_cache, new_v_cache).
    """
    geom = head_geom(cfg, tp_size())
    hd, kv, g = geom.head_dim, geom.n_kv, geom.group
    b, c, _ = x.shape
    s_max = k_cache.shape[1]

    q = (x @ p["wq"]).reshape(b, c, kv, g, hd)
    k_new = (x @ p["wk"]).reshape(b, c, kv, hd)
    v_new = (x @ p["wv"]).reshape(b, c, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_scale"], q, cfg.norm_eps)
        k_new = rmsnorm(p["k_scale"], k_new, cfg.norm_eps)
    idx = pos[:, None] + jnp.arange(c)[None, :]            # [B,C]
    if cfg.rope_theta > 0:
        qf = q.reshape(b, c, kv * g, hd)
        q = rope(qf, idx, cfg.rope_theta).reshape(b, c, kv, g, hd)
        k_new = rope(k_new, idx, cfg.rope_theta)

    # masked scatter: lanes write only their first n_new rows; out-of-range
    # indices (padding lanes, idle slots) drop instead of wrapping
    ok = jnp.arange(c)[None, :] < n_new[:, None]           # [B,C]
    safe = jnp.where(ok, idx, s_max)
    bi = jnp.broadcast_to(jnp.arange(b)[:, None], (b, c))
    k_cache = k_cache.at[bi, safe].set(k_new, mode="drop")
    v_cache = v_cache.at[bi, safe].set(v_new, mode="drop")

    scores = jnp.einsum("bckgh,bskh->bkgcs", q, k_cache,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    valid = jnp.arange(s_max)[None, None, :] <= idx[:, :, None]  # [B,C,S]
    scores = jnp.where(valid[:, None, None, :, :], scores, NEG_INF)
    # fp32 weights, round once after PV — see decode_attention
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", probs,
                     v_cache.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(b, c, kv * g * hd)
    out = constrain(out, ("act_batch", None, "act_heads"))
    y = out @ p["wo"]
    y = constrain(y, ("act_batch", None, None))
    return y, k_cache, v_cache


def paged_chunk_decode_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                                 k_pool: jax.Array, v_pool: jax.Array,
                                 page_table: jax.Array, pos: jax.Array,
                                 n_new: jax.Array):
    """Chunked decode directly over the paged KV pool — no dense per-slot
    working cache, no gather.

    x [B,C,D]; k/v_pool [num_blocks, block_size, KV, hd] (one layer of
    the shared device page pool); page_table [B, n_pages] int32 maps each
    lane's logical block index to its physical page; pos/n_new as in
    :func:`chunk_decode_attention`.

    Fresh K/V rows are scattered into the pool *through the page table*
    (each lane writes only its own private pages — shared, refcounted
    prefix pages are never a write target because writes start at
    ``pos >= matched_len`` and prefix matches are whole blocks), then
    attention reads every page via the Pallas kernel
    (``kernels.ops.paged_attention``; interpret mode off-accelerator).
    Under tensor parallelism the pure-JAX page-table reference lowers
    instead — still the paged pathway, just GSPMD-traceable.

    Returns (y [B,C,D], new_k_pool, new_v_pool).
    """
    geom = head_geom(cfg, tp_size())
    hd, kv, g = geom.head_dim, geom.n_kv, geom.group
    b, c, _ = x.shape
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    n_pages = page_table.shape[1]

    q = (x @ p["wq"]).reshape(b, c, kv, g, hd)
    k_new = (x @ p["wk"]).reshape(b, c, kv, hd)
    v_new = (x @ p["wv"]).reshape(b, c, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_scale"], q, cfg.norm_eps)
        k_new = rmsnorm(p["k_scale"], k_new, cfg.norm_eps)
    idx = pos[:, None] + jnp.arange(c)[None, :]            # [B,C]
    if cfg.rope_theta > 0:
        qf = q.reshape(b, c, kv * g, hd)
        q = rope(qf, idx, cfg.rope_theta).reshape(b, c, kv, g, hd)
        k_new = rope(k_new, idx, cfg.rope_theta)

    # masked scatter through the page table: row idx lands in physical
    # page ``table[idx // bs]`` at offset ``idx % bs``.  Lanes write only
    # their first n_new rows; anything out of range (idle slots, padding
    # rows, idx beyond the table) resolves to page ``nb`` and drops.
    ok = (jnp.arange(c)[None, :] < n_new[:, None]) & (idx < n_pages * bs)
    blk = jnp.clip(idx // bs, 0, n_pages - 1)
    page = jnp.take_along_axis(page_table, blk, axis=1)    # [B,C]
    page = jnp.where(ok, page, nb)
    off = idx % bs
    k_pool = k_pool.at[page, off].set(k_new, mode="drop")
    v_pool = v_pool.at[page, off].set(v_new, mode="drop")

    from repro.kernels import ops as kops
    if kops.use_paged_kernel() and tp_size() == 1:
        out = kops.paged_attention(q, k_pool, v_pool, page_table, pos, n_new)
    else:
        # pure-JAX page-table reference: the same paged pathway (no dense
        # working cache anywhere) with the dense path's exact rounding
        # points, so CPU serving stays bit-comparable to the contiguous
        # oracle; the Pallas kernel's online-softmax accumulation is
        # held to the ref by the kernel-parity suite instead
        from repro.kernels.paged_attention import paged_attention_ref
        out = paged_attention_ref(q, k_pool, v_pool, page_table, pos, n_new)
    out = out.reshape(b, c, kv * g * hd)
    out = constrain(out, ("act_batch", None, "act_heads"))
    y = out @ p["wo"]
    y = constrain(y, ("act_batch", None, None))
    return y, k_pool, v_pool
