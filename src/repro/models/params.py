"""Parameter specification trees.

Every model declares its parameters once as a pytree of ``ParamSpec`` —
shape, dtype, logical axes, initializer.  From that single declaration we
derive:

  * ``abstract(specs)``   → ShapeDtypeStruct tree (dry-run: no allocation)
  * ``initialize(specs)`` → materialized arrays (smoke tests / real runs)
  * ``partition(specs)``  → PartitionSpec tree via the bound rule set
  * ``count(specs)``      → analytic parameter count

This is the "version-pinned package list" of the environment manifest: the
model's state is fully described independently of any host binding.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import ctx as shardctx


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]               # logical axis name or None per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                # normal | zeros | ones | embed
    fan_in_axes: tuple[int, ...] = ()   # dims treated as fan-in for scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_map(fn: Callable[[ParamSpec], Any], specs: Any) -> Any:
    return jax.tree.map(fn, specs, is_leaf=is_spec)


def abstract(specs: Any) -> Any:
    return tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def partition(specs: Any) -> Any:
    """PartitionSpec tree under the currently bound shard context."""
    return tree_map(lambda s: shardctx.resolve(s.axes, s.shape), specs)


def shardings(specs: Any, mesh) -> Any:
    from jax.sharding import NamedSharding

    return tree_map(
        lambda s: NamedSharding(mesh, shardctx.resolve(s.axes, s.shape)), specs
    )


def count(specs: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(specs, is_leaf=is_spec):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        scale = spec.shape[-1] ** -0.5  # keeps tied-head logits O(1)
    else:
        fan_axes = spec.fan_in_axes or tuple(
            i for i in range(len(spec.shape) - 1)
            if spec.axes[i] not in ("layers", "groups")
        )
        fan_in = max(int(np.prod([spec.shape[i] for i in fan_axes])), 1)
        scale = fan_in ** -0.5
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def initialize(specs: Any, key: jax.Array) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    return jax.tree.unflatten(treedef, [_init_one(s, k) for s, k in zip(leaves, keys)])


# ---- spec constructors --------------------------------------------------

def dense(d_in: int, d_out: int, in_axis: str | None, out_axis: str | None,
          layers: int | None = None, dtype=jnp.bfloat16) -> ParamSpec:
    """[L?, d_in, d_out] projection."""
    shape: tuple[int, ...] = (d_in, d_out)
    axes: tuple[Any, ...] = (in_axis, out_axis)
    if layers is not None:
        shape = (layers,) + shape
        axes = ("layers",) + axes
    return ParamSpec(shape, axes, dtype)


def scale(d: int, layers: int | None = None, init: str = "ones") -> ParamSpec:
    shape: tuple[int, ...] = (d,)
    axes: tuple[Any, ...] = (None,)
    if layers is not None:
        shape = (layers,) + shape
        axes = ("layers",) + axes
    return ParamSpec(shape, axes, jnp.bfloat16, init=init)


def vec(shape: tuple[int, ...], axes: tuple[Any, ...], init: str = "zeros",
        dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(shape, axes, dtype, init=init)
