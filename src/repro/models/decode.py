"""Prefill and single-token decode paths with per-family cache layouts.

Cache shapes depend on the bound mesh (kv heads zero-padded to the model-
axis width so the cache itself shards) — so ``cache_specs`` must be called
under a bound shard context, mirroring the paper's late host binding.

decode shapes from the assignment lower ``decode_step`` (one new token
against a seq_len-deep cache), not ``train_step``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.models.attention import (chunk_decode_attention, decode_attention,
                                    full_attention,
                                    paged_chunk_decode_attention, tp_size)
from repro.models.layers import (embed_tokens, gelu_mlp, head_geom,
                                 logits_from, rmsnorm, sinusoidal_positions,
                                 swiglu)
from repro.models.moe import moe_ffn
from repro.models.ssm import conv_channels, mamba_decode
from repro.models.stack import _cross_block, _encoder, _res
from repro.parallel.ctx import constrain


def _kv_cache_spec(cfg: ModelConfig, layers: int, b: int, s: int) -> dict:
    geom = head_geom(cfg, tp_size())
    shape = (layers, b, s, geom.n_kv, geom.head_dim)
    if geom.n_kv % max(geom.tp, 1) == 0:
        # kv heads divide the model axis: shard the head dim (local scores)
        axes = ("layers", "cache_batch", "cache_seq", "cache_kv", None)
    else:
        # GQA with few kv heads: shard the SEQUENCE dim over the model axis
        # instead of padding heads — zero memory waste; softmax stats and
        # the [B,H,hd] partial-output reduce are the only collectives.
        axes = ("layers", "cache_batch", "cache_seq_tp", None, None)
    return {
        "k": P.ParamSpec(shape, axes, init="zeros"),
        "v": P.ParamSpec(shape, axes, init="zeros"),
    }


def _ssm_cache_spec(cfg: ModelConfig, layers: int, b: int) -> dict:
    cc = conv_channels(cfg)
    return {
        "conv": P.ParamSpec((layers, b, cfg.conv_width - 1, cc),
                            ("layers", "cache_batch", None, "act_inner"),
                            init="zeros"),
        "ssm": P.ParamSpec(
            (layers, b, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            ("layers", "cache_batch", "cache_kv", None, None),
            jnp.float32, init="zeros"),
    }


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict[str, Any]:
    """Cache ParamSpec tree for a decode step at the given geometry."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return {"self": _kv_cache_spec(cfg, cfg.n_layers, batch, seq_len)}
    if fam == "ssm":
        return {"ssm": _ssm_cache_spec(cfg, cfg.n_layers, batch)}
    if fam == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        n_mamba = groups * (cfg.attn_every - 1)
        return {
            "ssm": _ssm_cache_spec(cfg, n_mamba, batch),
            "self": _kv_cache_spec(cfg, groups, batch, seq_len),
        }
    if fam == "vlm":
        groups = cfg.n_layers // cfg.cross_every
        return {
            "self": _kv_cache_spec(cfg, cfg.n_layers, batch, seq_len),
            "cross": _kv_cache_spec(cfg, groups, batch, cfg.n_image_tokens),
        }
    if fam == "encdec":
        return {
            "self": _kv_cache_spec(cfg, cfg.n_layers, batch, seq_len),
            "cross": _kv_cache_spec(cfg, cfg.n_layers, batch,
                                    cfg.n_audio_frames),
        }
    raise ValueError(fam)


# ======================================================== compile watching


class CompileWatcher:
    """Cache-miss counter around a jitted step callable.

    Recompilation on the serving hot path is a pathway misconfiguration
    (shape polymorphism leaking into what must be a fixed-shape program):
    output stays token-identical while every new shape pays a full XLA
    compile.  The watcher keys each call by the argument tree's
    (shape, dtype) signature — a new key is a compile-cache miss — and
    cross-checks ``fn._cache_size()`` where the jit object exposes it, so
    same-shape recompiles (donation/layout churn) are counted too.

    ``on_compile(name, reason, signature)`` fires once per detected
    compile; engines wire it to their tracer.  Overhead per call is one
    tree flatten over a handful of arrays — noise next to a dispatched
    step.
    """

    def __init__(self, fn, name: str, on_compile=None):
        self.fn = fn
        self.name = name
        self.on_compile = on_compile
        self.calls = 0
        self.compiles = 0
        self._seen: set = set()
        self._base_cache: int | None = None
        self._first_arg_sig: tuple | None = None  # (arg ref, signature)

    @staticmethod
    def _leaf_sig(tree) -> tuple:
        return tuple(
            (tuple(x.shape), str(x.dtype))
            for x in jax.tree.leaves(tree)
            if hasattr(x, "shape") and hasattr(x, "dtype"))

    def _signature(self, args) -> tuple:
        """(shape, dtype) key of the argument tree.  The first argument
        is the params pytree — the same (large) object every call — so
        its sub-signature is computed once and reused by identity; the
        per-call cost is flattening only the small cache/token/pos args."""
        if not args:
            return ()
        first, rest = args[0], args[1:]
        if self._first_arg_sig is None or self._first_arg_sig[0] is not first:
            self._first_arg_sig = (first, self._leaf_sig(first))
        return self._first_arg_sig[1] + self._leaf_sig(rest)

    def _cache_size(self) -> int | None:
        probe = getattr(self.fn, "_cache_size", None)
        if not callable(probe):
            return None
        try:
            return probe()
        except Exception:  # noqa: BLE001 - diagnostic only, never fatal
            return None

    def _fire(self, reason: str, sig: tuple) -> None:
        self.compiles += 1
        if self.on_compile is not None:
            self.on_compile(self.name, reason, sig)

    def __call__(self, *args):
        if self.calls == 0:
            # baseline for a jit cache shared with other engines: growth
            # is judged relative to what was already compiled before us
            self._base_cache = self._cache_size()
        self.calls += 1
        sig = self._signature(args)
        if sig not in self._seen:
            self._seen.add(sig)
            self._fire("new-shapes", sig)
        out = self.fn(*args)
        n = self._cache_size()
        if (n is not None and self._base_cache is not None
                and n - self._base_cache > len(self._seen)):
            # more entries appeared than our shape keys explain: a
            # same-shape recompile (donation/layout churn)
            self._base_cache = n - len(self._seen)
            self._fire("cache-grew", sig)
        return out


# ================================================================= prefill


def _prefill_attn(cfg, p, x, pos0=0):
    """full attention that also emits (k, v) for the cache."""
    y, (k, v) = full_attention(cfg, p, x, pos0=pos0, return_kv=True)
    return y, k, v


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            cache_len: int | None = None):
    """Returns (last-position logits [B,Vpad], cache).  ``cache_len`` > prompt
    pre-allocates decode headroom (engine never reallocates mid-stream)."""
    fam = cfg.family
    geom = head_geom(cfg, tp_size()) if cfg.n_heads else None
    tokens = batch["tokens"]
    bsz, s = tokens.shape

    if fam in ("dense", "moe"):
        x = _res(embed_tokens(params["embed"], tokens))

        def body(x, p):
            h = rmsnorm(p["ln1"], x, cfg.norm_eps)
            a, k, v = _prefill_attn(cfg, p["attn"], h)
            x = x + a
            h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
            if fam == "moe":
                y, _ = moe_ffn(cfg, p["moe"], h2)
            else:
                y = swiglu(p["mlp"], h2)
            return _res(x + y), (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        cache = {"self": {"k": ks, "v": vs}}
    elif fam == "ssm":
        from repro.models.ssm import mamba_block_cp
        x = _res(embed_tokens(params["embed"], tokens))

        def body(x, p):
            h = rmsnorm(p["ln"], x, cfg.norm_eps)
            y, (conv_tail, ssm_state) = mamba_block_cp(
                cfg, p["mamba"], h, return_state=True)
            return _res(x + y), (conv_tail, ssm_state)

        x, (convs, ssms) = jax.lax.scan(body, x, params["layers"])
        cache = {"ssm": {"conv": convs, "ssm": ssms}}
    elif fam == "vlm":
        groups = cfg.n_layers // cfg.cross_every
        per = cfg.cross_every
        img = constrain(batch["image_embed"], ("act_batch", None, None))
        x = embed_tokens(params["embed"], tokens)
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])

        def group_body(x, gp):
            cross_p, layer_p = gp
            ck = (img @ cross_p["attn"]["wk"]).reshape(
                bsz, -1, geom.n_kv, geom.head_dim)
            cv = (img @ cross_p["attn"]["wv"]).reshape(
                bsz, -1, geom.n_kv, geom.head_dim)
            x = _cross_block(cfg, cross_p, x, img)

            def body(x, p):
                h = rmsnorm(p["ln1"], x, cfg.norm_eps)
                a, k, v = _prefill_attn(cfg, p["attn"], h)
                x = x + a
                return _res(x + swiglu(
                    p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))), (k, v)

            x, (ks, vs) = jax.lax.scan(body, x, layer_p)
            return x, (ks, vs, ck, cv)

        x, (ks, vs, cks, cvs) = jax.lax.scan(
            group_body, x, (params["cross"], stacked))
        lks = ks.reshape((cfg.n_layers,) + ks.shape[2:])
        lvs = vs.reshape((cfg.n_layers,) + vs.shape[2:])
        cache = {"self": {"k": lks, "v": lvs}, "cross": {"k": cks, "v": cvs}}
    elif fam == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        per = cfg.attn_every - 1
        x = embed_tokens(params["embed"], tokens)
        shared = params["shared"]
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])

        def group_body(x, gp):
            from repro.models.ssm import mamba_block_cp
            layer_p, site_norm = gp

            def inner(x, p):
                y, st = mamba_block_cp(cfg, p["mamba"],
                                       rmsnorm(p["ln"], x, cfg.norm_eps),
                                       return_state=True)
                return _res(x + y), st

            x, (convs, ssms) = jax.lax.scan(inner, x, layer_p)
            h = rmsnorm(site_norm,
                        rmsnorm(shared["ln_attn"], x, cfg.norm_eps),
                        cfg.norm_eps)
            a, k, v = _prefill_attn(cfg, shared["attn"], h)
            x = x + a
            x = _res(x + swiglu(shared["mlp"],
                                rmsnorm(shared["ln_mlp"], x, cfg.norm_eps)))
            return x, (convs, ssms, k, v)

        x, (convs, ssms, ks, vs) = jax.lax.scan(
            group_body, x, (stacked, params["site_norm"]))
        cache = {
            "ssm": {
                "conv": convs.reshape((groups * per,) + convs.shape[2:]),
                "ssm": ssms.reshape((groups * per,) + ssms.shape[2:]),
            },
            "self": {"k": ks, "v": vs},
        }
    elif fam == "encdec":
        enc = _encoder(cfg, params, batch["audio_embed"], "none")
        x = embed_tokens(params["embed"], tokens)
        x = x + sinusoidal_positions(s, cfg.d_model)

        def body(x, p):
            h = rmsnorm(p["ln1"], x, cfg.norm_eps)
            a, k, v = _prefill_attn(cfg, p["self"], h)
            x = x + a
            ck = (enc @ p["cross"]["wk"]).reshape(bsz, -1, geom.n_kv, geom.head_dim)
            cv = (enc @ p["cross"]["wv"]).reshape(bsz, -1, geom.n_kv, geom.head_dim)
            x = x + full_attention(cfg, p["cross"],
                                   rmsnorm(p["ln2"], x, cfg.norm_eps),
                                   kv_x=enc, causal=False)
            x = _res(x + gelu_mlp(p["mlp"], rmsnorm(p["ln3"], x, cfg.norm_eps)))
            return x, (k, v, ck, cv)

        x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["layers"])
        cache = {"self": {"k": ks, "v": vs}, "cross": {"k": cks, "v": cvs}}
    else:
        raise ValueError(fam)

    if cache_len is not None and cache_len > s and "self" in cache:
        pad = cache_len - s
        cache["self"] = jax.tree.map(
            lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            cache["self"])

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from(params["embed"], cfg, x[:, -1:, :])[:, 0]
    return logits, cache


# ================================================================== decode


def _idx(cache_arr: jax.Array, i: jax.Array) -> jax.Array:
    return jax.lax.dynamic_index_in_dim(cache_arr, i, 0, keepdims=False)


def _upd(cache_arr: jax.Array, new_layer: jax.Array, i: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_index_in_dim(cache_arr, new_layer, i, 0)


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos: jax.Array):
    """One-token decode.  token [B,1] int32, pos [B] int32.
    Returns (logits [B,Vpad] fp32, new cache).

    Caches ride the scan CARRY and are updated in place with
    dynamic-update-slice: with the cache argument donated, XLA aliases the
    buffer and the step's temp memory stays O(one layer) — emitting updated
    layers as stacked scan outputs instead double-buffers the whole cache
    (measured +2× cache bytes on the 32k cells)."""
    fam = cfg.family
    x = embed_tokens(params["embed"], token)

    if fam in ("dense", "moe"):
        def body(carry, xs):
            x, kc, vc = carry
            p, i = xs
            h = rmsnorm(p["ln1"], x, cfg.norm_eps)
            a, k_l, v_l = decode_attention(cfg, p["attn"], h,
                                           _idx(kc, i), _idx(vc, i), pos)
            x = x + a
            h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
            if fam == "moe":
                y, _ = moe_ffn(cfg, p["moe"], h2)
            else:
                y = swiglu(p["mlp"], h2)
            return (x + y, _upd(kc, k_l, i), _upd(vc, v_l, i)), None

        (x, ks, vs), _ = jax.lax.scan(
            body, (x, cache["self"]["k"], cache["self"]["v"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"self": {"k": ks, "v": vs}}
    elif fam == "ssm":
        def body(carry, xs):
            x, convs, ssms = carry
            p, i = xs
            h = rmsnorm(p["ln"], x, cfg.norm_eps)
            y, conv, ssm = mamba_decode(cfg, p["mamba"], h,
                                        _idx(convs, i), _idx(ssms, i))
            return (x + y, _upd(convs, conv, i), _upd(ssms, ssm, i)), None

        (x, convs, ssms), _ = jax.lax.scan(
            body, (x, cache["ssm"]["conv"], cache["ssm"]["ssm"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"ssm": {"conv": convs, "ssm": ssms}}
    elif fam == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        per = cfg.attn_every - 1
        shared = params["shared"]
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])

        def group_body(carry, xs):
            x, convs, ssms, kc, vc = carry
            layer_p, site_norm, g = xs

            def inner(icarry, ys):
                x, convs, ssms = icarry
                p, j = ys
                li = g * per + j
                h = rmsnorm(p["ln"], x, cfg.norm_eps)
                y, conv, ssm = mamba_decode(cfg, p["mamba"], h,
                                            _idx(convs, li), _idx(ssms, li))
                return (x + y, _upd(convs, conv, li), _upd(ssms, ssm, li)), None

            (x, convs, ssms), _ = jax.lax.scan(
                inner, (x, convs, ssms), (layer_p, jnp.arange(per)))
            h = rmsnorm(site_norm, rmsnorm(shared["ln_attn"], x, cfg.norm_eps),
                        cfg.norm_eps)
            a, k_g, v_g = decode_attention(cfg, shared["attn"], h,
                                           _idx(kc, g), _idx(vc, g), pos)
            x = x + a
            x = x + swiglu(shared["mlp"],
                           rmsnorm(shared["ln_mlp"], x, cfg.norm_eps))
            return (x, convs, ssms, _upd(kc, k_g, g), _upd(vc, v_g, g)), None

        (x, convs, ssms, ks, vs), _ = jax.lax.scan(
            group_body,
            (x, cache["ssm"]["conv"], cache["ssm"]["ssm"],
             cache["self"]["k"], cache["self"]["v"]),
            (stacked, params["site_norm"], jnp.arange(groups)))
        new_cache = {"ssm": {"conv": convs, "ssm": ssms},
                     "self": {"k": ks, "v": vs}}
    elif fam == "vlm":
        groups = cfg.n_layers // cfg.cross_every
        per = cfg.cross_every
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])

        def group_body(carry, xs):
            x, kc, vc = carry
            cross_p, layer_p, ck, cv, g = xs
            h = rmsnorm(cross_p["ln"], x, cfg.norm_eps)
            a, _, _ = decode_attention(cfg, cross_p["attn"], h, ck, cv, pos,
                                       update_cache=False)
            x = x + jnp.tanh(cross_p["gate_attn"]).astype(x.dtype) * a
            m = swiglu(cross_p["mlp"], rmsnorm(cross_p["ln_mlp"], x, cfg.norm_eps))
            x = x + jnp.tanh(cross_p["gate_mlp"]).astype(x.dtype) * m

            def inner(icarry, ys):
                x, kc, vc = icarry
                p, j = ys
                li = g * per + j
                h = rmsnorm(p["ln1"], x, cfg.norm_eps)
                a, k_l, v_l = decode_attention(cfg, p["attn"], h,
                                               _idx(kc, li), _idx(vc, li), pos)
                x = x + a
                x = x + swiglu(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
                return (x, _upd(kc, k_l, li), _upd(vc, v_l, li)), None

            (x, kc, vc), _ = jax.lax.scan(
                inner, (x, kc, vc), (layer_p, jnp.arange(per)))
            return (x, kc, vc), None

        (x, ks, vs), _ = jax.lax.scan(
            group_body, (x, cache["self"]["k"], cache["self"]["v"]),
            (params["cross"], stacked, cache["cross"]["k"],
             cache["cross"]["v"], jnp.arange(groups)))
        new_cache = {"self": {"k": ks, "v": vs}, "cross": cache["cross"]}
    elif fam == "encdec":
        x = x + sinusoidal_positions(1, cfg.d_model, offset=pos[:, None])[:, None, :]

        def body(carry, xs):
            x, kc, vc = carry
            p, ck, cv, i = xs
            h = rmsnorm(p["ln1"], x, cfg.norm_eps)
            a, k_l, v_l = decode_attention(cfg, p["self"], h,
                                           _idx(kc, i), _idx(vc, i), pos)
            x = x + a
            h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
            a2, _, _ = decode_attention(cfg, p["cross"], h2, ck, cv, pos,
                                        update_cache=False)
            x = x + a2
            x = x + gelu_mlp(p["mlp"], rmsnorm(p["ln3"], x, cfg.norm_eps))
            return (x, _upd(kc, k_l, i), _upd(vc, v_l, i)), None

        (x, ks, vs), _ = jax.lax.scan(
            body, (x, cache["self"]["k"], cache["self"]["v"]),
            (params["layers"], cache["cross"]["k"], cache["cross"]["v"],
             jnp.arange(cfg.n_layers)))
        new_cache = {"self": {"k": ks, "v": vs}, "cross": cache["cross"]}
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from(params["embed"], cfg, x)[:, 0]
    return logits, new_cache


# ============================================================ chunked decode


def decode_chunk(cfg: ModelConfig, params: dict, cache: dict,
                 tokens: jax.Array, pos: jax.Array, n_new: jax.Array):
    """C-token decode against the cache: the paged engine's single step.

    tokens [B,C] int32, pos [B] int32 (first write position per lane),
    n_new [B] int32 in [0, C] (how many of the lane's tokens are real; 0
    marks an idle slot, 1 is a plain decode tick, >1 is a prefill chunk).
    Prefill lanes consume C prompt tokens per call while decode lanes
    advance one token in the same batched step — chunked prefill without a
    second jitted program or shape polymorphism.

    Returns (logits [B,Vpad] at each lane's last real position, new cache).
    Only attention-cache families (dense/moe) support the chunked path;
    other families serve through the contiguous engine.
    """
    fam = cfg.family
    if fam not in ("dense", "moe"):
        raise ValueError(f"decode_chunk supports dense/moe caches, got {fam}")
    b = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens)

    def body(carry, xs):
        x, kc, vc = carry
        p, i = xs
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, kc_i, vc_i = chunk_decode_attention(cfg, p["attn"], h,
                                               _idx(kc, i), _idx(vc, i),
                                               pos, n_new)
        x = x + a
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if fam == "moe":
            y, _ = moe_ffn(cfg, p["moe"], h2)
        else:
            y = swiglu(p["mlp"], h2)
        return (x + y, _upd(kc, kc_i, i), _upd(vc, vc_i, i)), None

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache["self"]["k"], cache["self"]["v"]),
        (params["layers"], jnp.arange(cfg.n_layers)))

    last = jnp.maximum(n_new, 1) - 1
    x_last = x[jnp.arange(b), last][:, None, :]
    x_last = rmsnorm(params["final_norm"], x_last, cfg.norm_eps)
    logits = logits_from(params["embed"], cfg, x_last)[:, 0]
    return logits, {"self": {"k": ks, "v": vs}}


# ======================================================= paged chunked decode


def paged_cache_specs(cfg: ModelConfig, num_blocks: int,
                      block_size: int) -> dict[str, Any]:
    """Cache ParamSpec tree for the paged decode step: the KV lives in a
    shared page pool ``(layers, num_blocks, block_size, kv, hd)`` instead
    of dense per-slot rows.  Dense/moe only (attention caches)."""
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"paged cache supports dense/moe, got {cfg.family}")
    geom = head_geom(cfg, tp_size())
    shape = (cfg.n_layers, num_blocks, block_size, geom.n_kv, geom.head_dim)
    axes = ("layers", None, None, "cache_kv", None)
    return {"paged": {
        "k": P.ParamSpec(shape, axes, init="zeros"),
        "v": P.ParamSpec(shape, axes, init="zeros"),
    }}


def decode_paged_chunk(cfg: ModelConfig, params: dict, cache: dict,
                       tokens: jax.Array, pos: jax.Array, n_new: jax.Array,
                       page_table: jax.Array):
    """C-token decode straight over the paged KV pool: the kernel-enabled
    serving engine's single step.

    Same contract as :func:`decode_chunk` (tokens [B,C], pos [B], n_new
    [B]; logits at each lane's last real position) except the cache is
    ``{"paged": {"k", "v"}}`` — the shared page pool — and ``page_table``
    [B, n_pages] int32 maps each lane's logical blocks to physical
    pages.  Fresh KV rows are written through the table and attention
    reads through it (``kernels.paged_attention``): no dense per-slot
    working cache exists anywhere on this path.
    """
    fam = cfg.family
    if fam not in ("dense", "moe"):
        raise ValueError(f"decode_paged_chunk supports dense/moe, got {fam}")
    b = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens)

    def body(carry, xs):
        x, kp, vp = carry
        p, i = xs
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, kp_i, vp_i = paged_chunk_decode_attention(
            cfg, p["attn"], h, _idx(kp, i), _idx(vp, i),
            page_table, pos, n_new)
        x = x + a
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if fam == "moe":
            y, _ = moe_ffn(cfg, p["moe"], h2)
        else:
            y = swiglu(p["mlp"], h2)
        return (x + y, _upd(kp, kp_i, i), _upd(vp, vp_i, i)), None

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache["paged"]["k"], cache["paged"]["v"]),
        (params["layers"], jnp.arange(cfg.n_layers)))

    last = jnp.maximum(n_new, 1) - 1
    x_last = x[jnp.arange(b), last][:, None, :]
    x_last = rmsnorm(params["final_norm"], x_last, cfg.norm_eps)
    logits = logits_from(params["embed"], cfg, x_last)[:, 0]
    return logits, {"paged": {"k": ks, "v": vs}}


# ============================================================ fused sampling


def lane_keys(seed: jax.Array, rid: jax.Array, step: jax.Array) -> jax.Array:
    """Counter-based per-lane PRNG keys: ``key = fold(fold(PRNGKey(seed),
    rid), step)``.

    Purely a function of (seed, request_id, step) — no generator state —
    so the ``step``-th token of a request draws the same key on any
    engine, in any slot, under any schedule, and a preempted request
    recomputed from scratch resumes its stream exactly.  All inputs are
    ``[B]``; the derivation is vmapped so the jitted step stays one fixed
    shape.
    """
    def one(s, r, t):
        k = jax.random.fold_in(jax.random.PRNGKey(s), r)
        return jax.random.fold_in(k, t)

    return jax.vmap(one)(seed, rid, step)


def sample_from_logits(logits: jax.Array, lane: dict[str, jax.Array]
                       ) -> jax.Array:
    """Fused token selection: logits ``[B, V]`` -> tokens ``[B]`` int32.

    ``lane`` carries per-lane ``[B]`` arrays: ``rid``/``step``/``seed``
    (key derivation, see :func:`lane_keys`) and ``temperature``/
    ``top_k``/``top_p`` (filtering).  ``temperature <= 0`` selects exact
    greedy argmax for that lane (bit-identical to the pre-sampling
    engines); ``top_k <= 0`` means no k-limit.  Every op is fixed-shape
    in (B, V) regardless of the request mix — sampling introduces no
    shape polymorphism, hence no recompiles on the serving hot path.

    Filtering is rank-based on one descending sort: the top-k cut keeps
    logits >= the k-th largest, the nucleus cut keeps the smallest set of
    tokens whose exclusive cumulative probability stays under ``top_p``
    (the argmax token always survives both).  The surviving set is
    sampled via per-lane-keyed Gumbel argmax (``jax.random.categorical``).
    """
    b, v = logits.shape
    lg = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)

    temp = jnp.maximum(lane["temperature"], 1e-6)[:, None]
    scaled = lg / temp
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k_eff = jnp.where(lane["top_k"] > 0, lane["top_k"], v)
    kth = jnp.take_along_axis(
        sorted_desc, jnp.clip(k_eff - 1, 0, v - 1)[:, None], axis=-1)
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    cum_excl = jnp.cumsum(probs, axis=-1) - probs
    # top_p >= 1 means no nucleus cut at all: bypass the comparison so
    # float32 cumsum rounding can never mask extreme-tail tokens
    p_bound = jnp.where(lane["top_p"] >= 1.0, jnp.inf, lane["top_p"])
    keep = cum_excl < p_bound[:, None]            # row 0 always True
    p_floor = jnp.min(jnp.where(keep, sorted_desc, jnp.inf), axis=-1,
                      keepdims=True)
    masked = jnp.where((scaled >= kth) & (scaled >= p_floor), scaled,
                       -jnp.inf)

    keys = lane_keys(lane["seed"], lane["rid"], lane["step"])
    sampled = jax.vmap(jax.random.categorical)(keys, masked).astype(jnp.int32)
    return jnp.where(lane["temperature"] > 0.0, sampled, greedy_tok)
