"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort-based
dispatch, expert parallelism over the ``model`` axis via explicit
all-to-all inside shard_map.

TPU adaptation: GShard's one-hot dispatch einsum is O(N·D·E·C) — infeasible
at 128 experts — so we use the sort/scatter formulation (tokens sorted by
expert id, capacity-clipped, scatter-add into [E, cap, D] slots).  The two
``all_to_all`` collectives over the model axis are exactly the transport the
HLO inspector must see for an EP workload; a silent fallback to all-gather
here is the TPU analogue of the paper's "container fell back to TCP".
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as P
from repro.parallel.ctx import _current

from repro.parallel.ctx import shard_map_compat


def moe_specs(cfg: ModelConfig, layers: int | None) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    lyr = (layers,) if layers is not None else ()
    lax_ = ("layers",) if layers is not None else ()
    return {
        "router": P.ParamSpec(lyr + (d, e), lax_ + ("embed", None), jnp.float32),
        "gate": P.ParamSpec(lyr + (e, d, f), lax_ + ("experts", "embed", "mlp")),
        "up": P.ParamSpec(lyr + (e, d, f), lax_ + ("experts", "embed", "mlp")),
        "down": P.ParamSpec(lyr + (e, f, d), lax_ + ("experts", "mlp", "embed")),
    }


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, 1)


def _moe_local(cfg: ModelConfig, p: dict, x: jax.Array, a2a_axis: str | None,
               tp: int) -> tuple[jax.Array, jax.Array]:
    """Per-shard MoE body.  x: [b_loc, s, d].  Returns (y, aux[b_loc, s])."""
    e, k = cfg.n_experts, cfg.top_k
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ p["router"])  # [n, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)            # [n, k]
    top_w = (top_p / jnp.sum(top_p, axis=-1, keepdims=True)).astype(x.dtype)

    # Load-balancing aux (Switch): E * sum_e f_e * p_e, per shard.
    assign = jnp.zeros((n, e), jnp.float32).at[
        jnp.arange(n)[:, None], top_i].add(1.0)
    f_e = jnp.mean(assign, axis=0) / k
    p_e = jnp.mean(probs, axis=0)
    aux_val = e * jnp.sum(f_e * p_e)
    aux = jnp.full((b, s), aux_val, jnp.float32)

    # Sort-based capacity dispatch.
    cap = _capacity(n, cfg)
    flat_e = top_i.reshape(-1)                        # [n*k]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(n * k) - first
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
    token_of = order // k
    xs = xf[token_of] * keep[:, None].astype(xf.dtype)
    disp = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].add(xs)
    disp = disp[:-1].reshape(e, cap, d)

    if a2a_axis is not None and tp > 1:
        disp = jax.lax.all_to_all(disp, a2a_axis, split_axis=0, concat_axis=1,
                                  tiled=True)        # [E/tp, cap*tp, d]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, p["gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", disp, p["up"])
    y_e = jnp.einsum("ecf,efd->ecd", h, p["down"])    # [E_loc, cap*tp, d]

    if a2a_axis is not None and tp > 1:
        y_e = jax.lax.all_to_all(y_e, a2a_axis, split_axis=1, concat_axis=0,
                                 tiled=True)          # [E, cap, d]

    slots = jnp.concatenate(
        [y_e.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = slots[dest] * top_w.reshape(-1)[order][:, None]
    y = jnp.zeros((n, d), x.dtype).at[token_of].add(gathered)
    return y.reshape(b, s, d), aux


def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE FFN.  x: [B,S,D] -> (y [B,S,D], aux [B,S])."""
    ctx = _current()
    tp = ctx.axis_sizes.get("model", 1) if ctx else 1
    if ctx is None or tp == 1:
        return _moe_local(cfg, p, x, None, 1)

    mesh = ctx.mesh
    # tokens arrive residual-sharded (batch × seq-SP over model); dispatch is
    # local per shard, the two all_to_alls over `model` carry tokens to their
    # expert owners — MoE sequence-parallel dispatch, no all-gather needed.
    x_spec = ctx.resolve(("act_batch", "act_res", None), x.shape)
    w_e = jax.sharding.PartitionSpec("model", None, None)
    p_specs = {
        "router": jax.sharding.PartitionSpec(None, None),
        "gate": w_e, "up": w_e, "down": w_e,
    }
    aux_spec = ctx.resolve(("act_batch", "act_res"), (x.shape[0], x.shape[1]))

    body = partial(_moe_local, cfg, a2a_axis="model", tp=tp)

    def wrapped(p_loc, x_loc):
        return body(p_loc, x_loc)

    return shard_map_compat(
        wrapped, mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=(x_spec, aux_spec),
    )(p, x)
