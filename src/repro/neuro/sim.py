"""Bulk-synchronous distributed ring simulation (Arbor's execution model).

Arbor advances all cells independently for one min-delay window, then
exchanges the generated spikes with a global MPI_Allgather (§6.2.1 of the
paper).  The JAX-native mapping:

  MPI rank            -> shard_map shard over a 1D 'cells' mesh axis
  local cell update   -> inner lax.scan over dt steps (HH kernel hotspot)
  MPI_Allgather       -> jax.lax.all_gather of the epoch's spike matrix
  axonal delay        -> the exchange epoch length (spikes generated in
                         epoch k are applied in epoch k+1)

The same function runs single-device (tests) and sharded (benchmarks,
dry-run at production meshes) — the paper's portable-image property.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.neuro import cable
from repro.neuro.ring import RingConfig, is_ring_head, source_of

from repro.parallel.ctx import shard_map_compat


@dataclass
class SimResult:
    spike_counts: Any          # [N] int32 — spikes per cell
    total_spikes: int
    wavefront: Any             # [n_epochs] int32 — furthest spiking cell per epoch
    wall_s: float
    state: cable.CellState


def _epoch_fn(cfg: RingConfig, n_loc: int, axis: str | None,
              use_pallas: bool):
    heads_g = is_ring_head(cfg)
    sources_g = source_of(cfg)
    steps = cfg.delay_steps
    dt = cfg.cell.dt
    stim_steps = int(round(cfg.stim_ms / dt))

    def epoch(carry, epoch_idx):
        state, incoming = carry  # incoming: [steps, n_loc]
        if axis is not None:
            my_start = jax.lax.axis_index(axis) * n_loc
        else:
            my_start = 0
        heads = jax.lax.dynamic_slice(heads_g, (my_start,), (n_loc,))
        base_step = epoch_idx * steps

        def substep(st, inp):
            step_in_epoch, spikes_in = inp
            t_step = base_step + step_in_epoch
            i_ext = jnp.where(heads & (t_step < stim_steps),
                              cfg.stim_current, 0.0).astype(jnp.float32)
            st, spiked = cable.step(st, cfg.cell, spikes_in, i_ext,
                                    use_pallas=use_pallas)
            return st, spiked

        state, spiked = jax.lax.scan(
            substep, state, (jnp.arange(steps), incoming))
        # spikes travel as int8 (the paper's MPI_Allgather moves compact
        # spike records too): 4x less exchange traffic than f32 flags
        spiked_i = spiked.astype(jnp.int8)  # [steps, n_loc]

        # --- spike exchange (MPI_Allgather analogue) ---
        if axis is not None:
            gathered = jax.lax.all_gather(
                spiked_i, axis, axis=1, tiled=True)  # [steps, N]
        else:
            gathered = spiked_i
        src_ids = jax.lax.dynamic_slice(sources_g, (my_start,), (n_loc,))
        incoming_next = jnp.take(gathered, src_ids, axis=1).astype(jnp.float32)

        counts = jnp.sum(spiked, axis=0).astype(jnp.int32)  # [n_loc]
        front = jnp.max(jnp.where(
            jnp.any(spiked, axis=0), my_start + jnp.arange(n_loc), -1))
        if axis is not None:
            front = jax.lax.pmax(front, axis)
        return (state, incoming_next), (counts, front)

    return epoch


def _run_local(cfg: RingConfig, n_loc: int, axis: str | None,
               use_pallas: bool):
    epoch = _epoch_fn(cfg, n_loc, axis, use_pallas)

    def run(state: cable.CellState):
        incoming = jnp.zeros((cfg.delay_steps, n_loc), jnp.float32)
        (state, _), (counts, fronts) = jax.lax.scan(
            epoch, (state, incoming), jnp.arange(cfg.n_epochs))
        return state, jnp.sum(counts, axis=0), fronts

    return run


def simulate(cfg: RingConfig, *, mesh=None, axis: str = "cells",
             use_pallas: bool = False, jit: bool = True) -> SimResult:
    """Run the ring network.  ``mesh``: optional 1D Mesh to distribute
    cells over (n_cells must divide evenly); None = single device."""
    if mesh is not None:
        n_shards = mesh.devices.size
        assert cfg.n_cells % n_shards == 0
        n_loc = cfg.n_cells // n_shards
        run = _run_local(cfg, n_loc, axis, use_pallas)
        spec = jax.sharding.PartitionSpec(axis)
        state_specs = cable.CellState(
            v=spec, m=spec, h=spec, n=spec, g_syn=spec)
        fn = shard_map_compat(
            run, mesh=mesh, in_specs=(state_specs,),
            out_specs=(state_specs, spec, jax.sharding.PartitionSpec()))
    else:
        n_loc = cfg.n_cells
        fn = _run_local(cfg, n_loc, None, use_pallas)

    if jit:
        fn = jax.jit(fn)
    state0 = cable.init_state(cfg.n_cells, cfg.cell)
    if mesh is not None:
        sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(axis))
        state0 = jax.tree.map(lambda x: jax.device_put(x, sh), state0)

    # compile (excluded from wall time, reported separately by benchmarks)
    out = fn(state0)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    state, counts, fronts = fn(state0)
    jax.block_until_ready(counts)
    wall = time.perf_counter() - t0

    return SimResult(
        spike_counts=counts,
        total_spikes=int(jnp.sum(counts)),
        wavefront=fronts,
        wall_s=wall,
        state=state,
    )
