"""Ring-network construction (Arbor ring benchmark + NEURON ringtest).

Arbor's benchmark: N cable cells in a unidirectional ring, cell i receives
one excitatory synapse from cell i-1 (mod N) with fixed axonal delay; an
external stimulus kicks cell 0 and the action potential propagates around
the ring.  NEURON's ringtest: R independent rings (chains) of cells.

Both are the same object here: ``RingConfig(n_cells, n_rings)`` — with
n_rings=1 it is the Arbor ring; with n_rings=R the cells split into R
independent rings (cell -> cell+1 within its ring).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp

from repro.neuro.cable import CellConfig


@dataclass(frozen=True)
class RingConfig:
    n_cells: int = 512
    n_rings: int = 1
    delay_ms: float = 5.0            # axonal delay = BSP exchange epoch
    t_end_ms: float = 40.0
    stim_ms: float = 3.0             # stimulus duration into each ring head
    stim_current: float = 20.0
    cell: CellConfig = field(default_factory=CellConfig)

    @property
    def cells_per_ring(self) -> int:
        assert self.n_cells % self.n_rings == 0
        return self.n_cells // self.n_rings

    @property
    def delay_steps(self) -> int:
        return max(int(round(self.delay_ms / self.cell.dt)), 1)

    @property
    def n_epochs(self) -> int:
        total_steps = int(round(self.t_end_ms / self.cell.dt))
        return max(total_steps // self.delay_steps, 1)


def source_of(cfg: RingConfig) -> jnp.ndarray:
    """Global presynaptic source id for every cell (ring wiring)."""
    ids = jnp.arange(cfg.n_cells)
    ring = ids // cfg.cells_per_ring
    pos = ids % cfg.cells_per_ring
    prev_pos = (pos - 1) % cfg.cells_per_ring
    return ring * cfg.cells_per_ring + prev_pos


def is_ring_head(cfg: RingConfig) -> jnp.ndarray:
    """Cells that receive the external stimulus (cell 0 of each ring)."""
    return (jnp.arange(cfg.n_cells) % cfg.cells_per_ring) == 0
