"""Hodgkin–Huxley cable cells — the paper's application workload.

Arbor's ring benchmark uses morphologically detailed cable cells: an HH
soma plus passive dendrite compartments.  We reproduce that structure:
compartment 0 carries the full HH mechanism and the synapse; compartments
1..C-1 are passive cable, coupled by axial conductance (explicit stencil).
Gates use exponential-Euler at dt=0.025 ms (Arbor defaults); Arbor's
implicit cable solve is replaced by an explicit stencil — the data flow
(and therefore the systems behaviour being benchmarked) is identical, the
numerics are standard for benchmark workloads.  Units: mV, ms, mS/cm².
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

# classic HH constants
C_M = 1.0
G_NA, E_NA = 120.0, 50.0
G_K, E_K = 36.0, -77.0
G_L, E_L = 0.3, -54.4
E_SYN = 0.0
V_REST = -65.0
V_THRESH = -20.0  # upward crossing = spike


@dataclass(frozen=True)
class CellConfig:
    n_compartments: int = 32
    g_axial: float = 0.5       # coupling conductance between compartments
    g_pas: float = 0.1         # passive leak in dendrite
    e_pas: float = -65.0
    tau_syn: float = 2.0       # ms, exponential synapse
    syn_weight: float = 2.0    # conductance increment per spike
    dt: float = 0.025          # ms (Arbor/NEURON benchmark step)


class CellState(NamedTuple):
    v: jax.Array       # [n, C]
    m: jax.Array       # [n]
    h: jax.Array       # [n]
    n: jax.Array       # [n]
    g_syn: jax.Array   # [n]


def init_state(n_cells: int, cfg: CellConfig) -> CellState:
    v = jnp.full((n_cells, cfg.n_compartments), V_REST, jnp.float32)
    # steady-state gates at rest
    a_m, b_m = _alpha_m(V_REST), _beta_m(V_REST)
    a_h, b_h = _alpha_h(V_REST), _beta_h(V_REST)
    a_n, b_n = _alpha_n(V_REST), _beta_n(V_REST)
    return CellState(
        v=v,
        m=jnp.full((n_cells,), a_m / (a_m + b_m), jnp.float32),
        h=jnp.full((n_cells,), a_h / (a_h + b_h), jnp.float32),
        n=jnp.full((n_cells,), a_n / (a_n + b_n), jnp.float32),
        g_syn=jnp.zeros((n_cells,), jnp.float32),
    )


# --- rate functions (vtrap-safe forms) ---
def _vtrap(x, y):
    return jnp.where(jnp.abs(x / y) < 1e-6, y * (1 - x / y / 2), x / (jnp.exp(x / y) - 1.0))


def _alpha_m(v):
    return 0.1 * _vtrap(-(v + 40.0), 10.0)


def _beta_m(v):
    return 4.0 * jnp.exp(-(v + 65.0) / 18.0)


def _alpha_h(v):
    return 0.07 * jnp.exp(-(v + 65.0) / 20.0)


def _beta_h(v):
    return 1.0 / (jnp.exp(-(v + 35.0) / 10.0) + 1.0)


def _alpha_n(v):
    return 0.01 * _vtrap(-(v + 55.0), 10.0)


def _beta_n(v):
    return 0.125 * jnp.exp(-(v + 65.0) / 80.0)


def hh_soma_update(v0, m, h, n, g_syn, i_axial, dt, i_ext):
    """Exponential-Euler update of the HH soma.  All inputs [n] f32.
    This is the compute hotspot (kernels/hh_neuron.py implements it as a
    Pallas kernel; this jnp body doubles as its oracle)."""
    a_m, b_m = _alpha_m(v0), _beta_m(v0)
    a_h, b_h = _alpha_h(v0), _beta_h(v0)
    a_n, b_n = _alpha_n(v0), _beta_n(v0)

    def gate(x, a, b):
        tau = 1.0 / (a + b)
        inf = a * tau
        return inf + (x - inf) * jnp.exp(-dt / tau)

    m_n = gate(m, a_m, b_m)
    h_n = gate(h, a_h, b_h)
    n_n = gate(n, a_n, b_n)

    g_na = G_NA * (m_n ** 3) * h_n
    g_k = G_K * (n_n ** 4)
    g_tot = g_na + g_k + G_L + g_syn
    i_inf = g_na * E_NA + g_k * E_K + G_L * E_L + g_syn * E_SYN + i_axial + i_ext
    v_inf = i_inf / g_tot
    v_n = v_inf + (v0 - v_inf) * jnp.exp(-dt * g_tot / C_M)
    return v_n, m_n, h_n, n_n


def step(state: CellState, cfg: CellConfig, spike_in: jax.Array,
         i_ext: jax.Array, *, use_pallas: bool = False):
    """One dt step.  spike_in: [n] float (1.0 = presynaptic spike arrives
    this step); i_ext: [n] external current into the soma.
    Returns (new_state, spiked [n] bool)."""
    v, m, h, n, g = state
    dt = cfg.dt

    # synapse: exponential decay + event increments
    g = g * jnp.exp(-dt / cfg.tau_syn) + cfg.syn_weight * spike_in

    # cable stencil (explicit): i_axial into each compartment
    left = jnp.pad(v[:, :-1], ((0, 0), (1, 0)), mode="edge")
    right = jnp.pad(v[:, 1:], ((0, 0), (0, 1)), mode="edge")
    i_axial = cfg.g_axial * (left - 2.0 * v + right)

    # passive dendrite compartments (1..C-1)
    v_dend = v[:, 1:]
    dv = (i_axial[:, 1:] + cfg.g_pas * (cfg.e_pas - v_dend)) * (dt / C_M)
    v_dend_new = v_dend + dv

    # HH soma (compartment 0)
    if use_pallas:
        from repro.kernels import ops as kops
        v0n, mn, hn, nn = kops.hh_step(v[:, 0], m, h, n, g,
                                       i_axial[:, 0], dt, i_ext)
    else:
        v0n, mn, hn, nn = hh_soma_update(v[:, 0], m, h, n, g,
                                         i_axial[:, 0], dt, i_ext)

    spiked = (v0n >= V_THRESH) & (v[:, 0] < V_THRESH)
    v_new = jnp.concatenate([v0n[:, None], v_dend_new], axis=1)
    return CellState(v_new, mn, hn, nn, g), spiked
