"""Sharded, content-hashed, atomically-committed checkpoints.

Design constraints from the paper's build-flow insight (§4.2.2): shared HPC
filesystems die by inode exhaustion, not capacity — so a checkpoint is a
FEW LARGE FILES per host (one .npz per host + one manifest), never
one-file-per-tensor.  Fault-tolerance requirements (1000+ node deployments):

  * atomic commit — write to ``step_N.tmp/``, fsync, rename; a crashed
    writer never corrupts the latest checkpoint;
  * integrity — every shard file carries a sha256; restore verifies;
  * elastic restore — the checkpoint stores the *global* array layout;
    ``restore`` reshards onto whatever mesh the new job binds
    (N→M host/device changes are transparent);
  * self-describing — the manifest embeds the environment manifest
    (core/manifest.py) so a restored run can detect drift.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: dict | None = None) -> Path:
        t0 = time.time()
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        flat = _flatten(state)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        # npz cannot round-trip ml_dtypes (bfloat16 etc.): store a uint view
        # and record the logical dtype in the manifest.
        storable = {
            k: (a.view(np.uint16) if a.dtype.name == "bfloat16" else a)
            for k, a in arrays.items()
        }
        shard_file = tmp / f"host_{jax.process_index():05d}.npz"
        np.savez(shard_file, **storable)
        digest = hashlib.sha256(shard_file.read_bytes()).hexdigest()

        manifest = {
            "step": step,
            "format": 1,
            "n_hosts": jax.process_count(),
            "keys": sorted(arrays),
            "shapes": {k: list(a.shape) for k, a in arrays.items()},
            "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
            "sha256": {shard_file.name: digest},
            "wall_s": None,
            "extra": extra or {},
        }
        manifest["wall_s"] = round(time.time() - t0, 3)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))

        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic commit
        self._gc()
        return final

    # ---------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, step: int | None, like: Any,
                shardings: Any | None = None, verify: bool = True) -> Any:
        """Restore onto the CURRENT mesh (elastic: `shardings` may describe
        a different device count than the writer had)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        shard_file = path / f"host_{jax.process_index():05d}.npz"
        if not shard_file.exists():  # elastic: fewer hosts than writer
            shard_file = sorted(path.glob("host_*.npz"))[0]
        if verify and shard_file.name in manifest["sha256"]:
            digest = hashlib.sha256(shard_file.read_bytes()).hexdigest()
            if digest != manifest["sha256"][shard_file.name]:
                raise IOError(f"checksum mismatch in {shard_file}")
        data = np.load(shard_file)

        flat_like = _flatten(like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, leaf in flat_like.items():
            arr = data[key]
            if manifest["dtypes"].get(key) == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if list(arr.shape) != list(np.shape(leaf)):
                raise ValueError(
                    f"{key}: checkpoint {arr.shape} vs expected {np.shape(leaf)}")
            target_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            ja = jnp.asarray(arr, dtype=target_dtype)
            if key in flat_sh and flat_sh[key] is not None:
                ja = jax.device_put(ja, flat_sh[key])
            out[key] = ja

        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
        new_leaves = []
        for path_k, _ in leaves_with_path:
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in path_k)
            new_leaves.append(out[key])
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    def _gc(self) -> None:
        steps = sorted(
            (int(p.name.split("_")[1]), p) for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp"))
        for _, p in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(p)
