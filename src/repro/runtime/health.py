"""Failure detection and the restart protocol (simulated multi-host).

At 1000+ nodes the failure model is: some host stops heartbeating; the job
must (a) notice within a bounded window, (b) decide whether to wait
(transient) or rebuild (hard failure), and (c) restart from the last
committed checkpoint on the surviving mesh (elastic) or on a replacement
allocation.  On a real cluster the heartbeat transport is the coordinator
(jax.distributed) or Slurm's job-step state; here the registry is
process-local and the tests drive it with synthetic clocks — the decision
logic is what matters and is identical.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable


class HostState(Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclass
class HostRecord:
    host_id: int
    last_beat: float
    state: HostState = HostState.HEALTHY
    incarnation: int = 0


@dataclass
class HealthRegistry:
    """Phi-accrual-lite failure detector: suspect after ``suspect_s``
    without a heartbeat, dead after ``dead_s``."""

    n_hosts: int
    suspect_s: float = 10.0
    dead_s: float = 60.0
    clock: Callable[[], float] = time.monotonic  # injectable for tests
    hosts: dict[int, HostRecord] = field(default_factory=dict)

    def __post_init__(self):
        now = self.clock()
        for h in range(self.n_hosts):
            self.hosts[h] = HostRecord(h, now)

    def beat(self, host_id: int) -> None:
        rec = self.hosts[host_id]
        if rec.state == HostState.DEAD:
            rec.incarnation += 1  # host came back: new incarnation
        rec.last_beat = self.clock()
        rec.state = HostState.HEALTHY

    def sweep(self) -> dict[int, HostState]:
        now = self.clock()
        for rec in self.hosts.values():
            silence = now - rec.last_beat
            if silence >= self.dead_s:
                rec.state = HostState.DEAD
            elif silence >= self.suspect_s:
                rec.state = HostState.SUSPECT
        return {h: r.state for h, r in self.hosts.items()}

    @property
    def survivors(self) -> list[int]:
        self.sweep()
        return [h for h, r in self.hosts.items() if r.state != HostState.DEAD]

    @property
    def healthy(self) -> bool:
        return len(self.survivors) == self.n_hosts



@dataclass
class RestartPlan:
    """What the controller does after a failure sweep."""

    action: str                 # continue | wait | rebuild
    mesh_hosts: list[int]
    restore_step: int | None = None
    reason: str = ""


def plan_restart(registry: HealthRegistry, last_checkpoint: int | None,
                 min_hosts: int, grace_s: float, silence_s: float) -> RestartPlan:
    """The restart protocol:
      * all healthy               -> continue
      * suspects within grace     -> wait (transient network blips)
      * dead hosts, enough left   -> rebuild elastic mesh from survivors,
                                     restore last checkpoint
      * too few survivors         -> wait for replacement allocation
    """
    states = registry.sweep()
    survivors = [h for h, s in states.items() if s != HostState.DEAD]
    suspects = [h for h, s in states.items() if s == HostState.SUSPECT]
    dead = [h for h, s in states.items() if s == HostState.DEAD]

    if not suspects and not dead:
        return RestartPlan("continue", survivors, reason="all healthy")
    if suspects and not dead and silence_s < grace_s:
        return RestartPlan("wait", survivors,
                           reason=f"suspects {suspects} within grace window")
    if dead and len(survivors) >= min_hosts:
        return RestartPlan("rebuild", survivors, restore_step=last_checkpoint,
                           reason=f"dead {dead}; elastic rebuild on "
                                  f"{len(survivors)} survivors")
    return RestartPlan("wait", survivors,
                       reason=f"only {len(survivors)} survivors < {min_hosts};"
                              " awaiting replacement allocation")
