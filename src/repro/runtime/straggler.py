"""Straggler mitigation for the synchronous training loop.

At pod scale the step time is the MAX over hosts; persistent stragglers
(thermals, failing HBM, noisy neighbours on shared fabric) drag the fleet.
Two mitigations, both standard in large production runs:

  * detection — per-host step-time EWMA vs fleet median; a host whose
    EWMA exceeds ``threshold`` × median for ``patience`` consecutive steps
    is flagged (and fed to the health registry / reallocation policy);
  * data-path absorption — the input pipeline keeps a prefetch depth of
    ``bound`` steps per host, so transient stalls (GC, filesystem hiccups)
    do not propagate into the collective; the tracker reports how much of
    the budget each host consumes.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np


@dataclass
class StragglerTracker:
    n_hosts: int
    alpha: float = 0.2          # EWMA coefficient
    threshold: float = 1.5      # × fleet median
    patience: int = 5
    ewma: dict[int, float] = field(default_factory=dict)
    strikes: dict[int, int] = field(default_factory=lambda: defaultdict(int))

    def observe(self, step_times: dict[int, float]) -> list[int]:
        """Record one step's per-host wall times; returns flagged hosts."""
        for h, t in step_times.items():
            prev = self.ewma.get(h, t)
            self.ewma[h] = (1 - self.alpha) * prev + self.alpha * t
        med = float(np.median(list(self.ewma.values())))
        flagged = []
        for h, e in self.ewma.items():
            if e > self.threshold * med:
                self.strikes[h] += 1
                if self.strikes[h] >= self.patience:
                    flagged.append(h)
            else:
                self.strikes[h] = 0
        return flagged

    def fleet_efficiency(self) -> float:
        """median/max of EWMAs — the fraction of sync-step time that is
        fleet-wide useful (1.0 = no straggling)."""
        if not self.ewma:
            return 1.0
        vals = list(self.ewma.values())
        return float(np.median(vals) / max(max(vals), 1e-12))
