"""Pallas TPU kernel: decode attention through the device page table.

The serving engine's KV lives in a shared page pool; each slot owns an
ordered list of pages (its page *table* row).  The previous pathway
gathered those pages into a dense per-slot working cache before every
attention call — exactly the contiguous-shaped host detour the audit
layer exists to flag.  This kernel consumes the paged layout directly:

  * grid ``(slots, kv_heads, pages)`` with the page dimension sequential,
    so the flash running max / denominator / accumulator live in VMEM
    scratch across a slot's pages;
  * the page table rides scalar prefetch
    (``pltpu.PrefetchScalarGridSpec``): the K/V block index maps read
    ``page_table[slot, page]`` to fetch the *physical* page, which is
    how refcount-shared prefix pages are attended by many slots with
    zero copies;
  * per-lane sequence state (``pos`` rows already written, ``n_new``
    fresh rows this call) is prefetched too: ragged last pages and the
    causal chunk mask (query ``i`` sees positions ``<= pos + i``) are
    masked inside the kernel, and pages past a lane's last valid row
    issue no MXU work at all (the same block-skipping economics as the
    causal flash kernel);
  * one kernel covers the whole chunked-serving step: ``C`` queries per
    lane, so prefill chunks (``n_new > 1``), plain decode ticks
    (``n_new == 1``) and idle lanes (``n_new == 0``, outputs discarded)
    share one fixed-shape program.

``paged_attention_ref`` is the pure-JAX oracle — the same math via a
dense gather *through the page table* — used by the parity tests and as
the dispatch fallback when the kernel cannot run (TP-sharded decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pt_ref, pos_ref, nn_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, block_size: int,
                  chunk: int, group: int, n_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[b]
    nn = nn_ref[b]
    # rows valid for this lane after its chunk is written: idle lanes
    # (nn == 0) still visit page 0 so the (discarded) output is finite
    total = pos + jnp.maximum(nn, 1)
    last = jnp.minimum((total - 1) // block_size, n_pages - 1)

    @pl.when(j <= last)
    def _compute():
        cg = chunk * group
        hd = q_ref.shape[-1]
        q = q_ref[0, :, 0].reshape(cg, hd).astype(jnp.float32)
        k = k_ref[0, :, 0].astype(jnp.float32)      # [bs, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [cg, bs]
        # causal chunk mask on *physical* positions: query row i (rows
        # are [chunk, group] flattened) attends cache slots <= pos + i —
        # this both hides the ragged tail of the last page and keeps a
        # chunk causally exact against itself
        k_pos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (cg, block_size), 1)
        row = jax.lax.broadcasted_iota(
            jnp.int32, (cg, block_size), 0) // group
        s = jnp.where(k_pos <= pos + row, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == last)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out = acc_ref[...] / denom[:, None]
        o_ref[0, :, 0] = out.reshape(chunk, group,
                                     acc_ref.shape[-1]).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pool, v_pool, page_table, pos, n_new, *,
                           scale: float | None = None,
                           interpret: bool = False):
    """Decode/chunk attention over the paged KV pool.

    q          [B, C, KV, G, hd] — post-RoPE queries (C chunk positions)
    k/v_pool   [num_blocks, block_size, KV, hd] — the shared page pool,
               already holding this call's fresh rows (writes go through
               the page table *before* attention, mirroring the dense
               path's update-then-attend order)
    page_table [B, n_pages] int32 — per-slot physical page indices; rows
               past a slot's allocation must hold a valid index (0) —
               they are masked, never out-of-bounds
    pos        [B] int32 — rows already in the cache per lane
    n_new      [B] int32 — fresh rows this call (0 = idle lane)

    Returns [B, C, KV, G, hd].  Rows ``>= n_new`` per lane are garbage
    the caller discards (same contract as ``chunk_decode_attention``).
    """
    b, c, kv, g, hd = q.shape
    nb, bs, kv_p, hd_p = k_pool.shape
    assert (kv_p, hd_p) == (kv, hd), (k_pool.shape, q.shape)
    assert v_pool.shape == k_pool.shape
    n_pages = page_table.shape[1]
    assert page_table.shape == (b, n_pages)
    scale = scale if scale is not None else hd ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kv, n_pages),
        in_specs=[
            pl.BlockSpec((1, c, 1, g, hd),
                         lambda b, h, j, pt, pos, nn: (b, 0, h, 0, 0)),
            # the paged read: physical page via the prefetched table
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, pt, pos, nn: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, pt, pos, nn: (pt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, 1, g, hd),
                               lambda b, h, j, pt, pos, nn: (b, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c * g,), jnp.float32),      # running max
            pltpu.VMEM((c * g,), jnp.float32),      # running denominator
            pltpu.VMEM((c * g, hd), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, block_size=bs,
                          chunk=c, group=g, n_pages=n_pages),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(page_table, pos, n_new, q, k_pool, v_pool)


def paged_attention_ref(q, k_pool, v_pool, page_table, pos, n_new, *,
                        scale: float | None = None):
    """Pure-JAX oracle: dense gather *through the page table* + masked
    softmax.  Bitwise-independent of the kernel (full softmax instead of
    the online accumulation) but mathematically identical on valid rows."""
    b, c, kv, g, hd = q.shape
    nb, bs, _, _ = k_pool.shape
    n_pages = page_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5

    k = k_pool[page_table].reshape(b, n_pages * bs, kv, hd)
    v = v_pool[page_table].reshape(b, n_pages * bs, kv, hd)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bckgh,bskh->bkgcs", qf, k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    idx = pos[:, None] + jnp.arange(c)[None, :]               # [B, C]
    valid = jnp.arange(n_pages * bs)[None, None, :] <= idx[:, :, None]
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
