"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Per (batch, head) the sequence splits into chunks of Q tokens.  Each grid
step computes the chunk's quadratic intra-chunk term on the MXU
(C·Bᵀ ⊙ decay masks — [Q,Q]×[Q,P] matmuls) and carries the [P,N] SSM
state across chunks in VMEM scratch (the chunk axis is sequential).
This is the TPU-native expression of the state-space duality: the paper's
GPU kernel tiles over SMs; here the chunk is sized so (x, B, C, CB, state)
fit VMEM and the [Q,Q]@[Q,P] / [Q,N]@[N,P] contractions are MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fin_ref,
                state_ref, *, n_chunks: int):
    cb_idx = pl.program_id(2)

    @pl.when(cb_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # [Q]
    a = a_ref[0]                                   # scalar
    b = b_ref[0, :, 0, :].astype(jnp.float32)      # [Q, N]
    c = c_ref[0, :, 0, :].astype(jnp.float32)      # [Q, N]

    da = dt * a                                    # [Q]
    seg = jnp.cumsum(da)                           # [Q]

    # intra-chunk: (C Bᵀ ⊙ L ⊙ dt_k) x
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    decay = jnp.exp(seg[:, None] - seg[None, :])
    q = seg.shape[0]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    m = jnp.where(tri, cb * decay, 0.0) * dt[None, :]
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q,P]

    # inter-chunk: C_q exp(seg_q) · S_prev
    state = state_ref[...]                         # [P, N]
    c_scaled = c * jnp.exp(seg)[:, None]           # [Q, N]
    y += jax.lax.dot_general(c_scaled, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,P]

    # state update: S = S·exp(sum da) + xᵀ (B ⊙ w_k)
    w_k = jnp.exp(seg[-1] - seg) * dt              # [Q]
    bw = b * w_k[:, None]                          # [Q, N]
    contrib = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # [P,N]
    state_ref[...] = state * jnp.exp(seg[-1]) + contrib

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(cb_idx == n_chunks - 1)
    def _fin():
        fin_ref[0, 0] = state_ref[...]


def ssd_scan_pallas(x, dt, a, b_in, c_in, chunk: int, *,
                    interpret: bool = False):
    """x: [B,S,H,P], dt: [B,S,H] f32, a: [H] f32, b_in/c_in: [B,S,G,N]
    (groups broadcast to heads by the wrapper).  Returns
    (y [B,S,H,P], final_state [B,H,P,N] f32)."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hg = h // g

    grid = (bsz, h, nc)
    kernel = functools.partial(_ssd_kernel, n_chunks=nc)

    y, fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, hg=hg: (bi, ci, hi // hg, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, hg=hg: (bi, ci, hi // hg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, jnp.asarray(dt, jnp.float32), jnp.asarray(a, jnp.float32),
      b_in, c_in)
    return y, fin
