"""Pallas TPU kernel: fused Hodgkin–Huxley soma update.

The inner loop of the Arbor/NEURON workload: per dt step, every cell's
gates (m, h, n) and soma voltage advance by exponential Euler.  It is
VPU-bound (transcendental-heavy, no matmul), so the kernel's job is to
fuse the ~40 elementwise ops into one VMEM-resident pass over the cell
block instead of XLA's many HBM round-trips.

Layout: cells reshaped to [rows, 128] so blocks are (8k, 128) —
hardware-aligned for the 8×128 VPU lanes.  One grid step per row block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# HH constants (must match neuro/cable.py — ref.py asserts this)
C_M = 1.0
G_NA, E_NA = 120.0, 50.0
G_K, E_K = 36.0, -77.0
G_L, E_L = 0.3, -54.4
E_SYN = 0.0

LANE = 128
DEFAULT_BLOCK_ROWS = 8


def _vtrap(x, y):
    return jnp.where(jnp.abs(x / y) < 1e-6,
                     y * (1 - x / y / 2), x / (jnp.exp(x / y) - 1.0))


def _hh_kernel(v_ref, m_ref, h_ref, n_ref, g_ref, iax_ref, iext_ref,
               vo_ref, mo_ref, ho_ref, no_ref, *, dt: float):
    v0 = v_ref[...]
    m, h, n = m_ref[...], h_ref[...], n_ref[...]
    g_syn = g_ref[...]
    i_axial, i_ext = iax_ref[...], iext_ref[...]

    a_m = 0.1 * _vtrap(-(v0 + 40.0), 10.0)
    b_m = 4.0 * jnp.exp(-(v0 + 65.0) / 18.0)
    a_h = 0.07 * jnp.exp(-(v0 + 65.0) / 20.0)
    b_h = 1.0 / (jnp.exp(-(v0 + 35.0) / 10.0) + 1.0)
    a_n = 0.01 * _vtrap(-(v0 + 55.0), 10.0)
    b_n = 0.125 * jnp.exp(-(v0 + 65.0) / 80.0)

    def gate(x, a, b):
        tau = 1.0 / (a + b)
        inf = a * tau
        return inf + (x - inf) * jnp.exp(-dt / tau)

    m_n = gate(m, a_m, b_m)
    h_n = gate(h, a_h, b_h)
    n_n = gate(n, a_n, b_n)

    g_na = G_NA * (m_n * m_n * m_n) * h_n
    g_k = G_K * (n_n * n_n * n_n * n_n)
    g_tot = g_na + g_k + G_L + g_syn
    i_inf = (g_na * E_NA + g_k * E_K + G_L * E_L + g_syn * E_SYN
             + i_axial + i_ext)
    v_inf = i_inf / g_tot
    v_n = v_inf + (v0 - v_inf) * jnp.exp(-dt * g_tot / C_M)

    vo_ref[...] = v_n
    mo_ref[...] = m_n
    ho_ref[...] = h_n
    no_ref[...] = n_n


def hh_step_pallas(v0, m, h, n, g_syn, i_axial, i_ext, *, dt: float,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False):
    """[N]-shaped f32 inputs; returns (v, m, h, n) updated.  Pads N up to a
    whole number of (block_rows × 128) tiles."""
    n_cells = v0.shape[0]
    tile = block_rows * LANE
    n_pad = (n_cells + tile - 1) // tile * tile

    def prep(x):
        x = jnp.asarray(x, jnp.float32)
        if n_pad != n_cells:
            x = jnp.pad(x, (0, n_pad - n_cells))
        return x.reshape(n_pad // LANE, LANE)

    args = [prep(x) for x in
            (v0, m, h, n, g_syn, i_axial,
             jnp.broadcast_to(i_ext, v0.shape))]
    rows = n_pad // LANE
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_sds = jax.ShapeDtypeStruct((rows, LANE), jnp.float32)

    outs = pl.pallas_call(
        functools.partial(_hh_kernel, dt=dt),
        grid=grid,
        in_specs=[spec] * 7,
        out_specs=[spec] * 4,
        out_shape=[out_sds] * 4,
        interpret=interpret,
    )(*args)
    return tuple(o.reshape(n_pad)[:n_cells] for o in outs)
