"""Jitted public wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; on CPU they execute in
interpret mode (the kernel body runs in Python per grid step) — that is
the validation path this container supports.  Model code calls these via
``use_pallas=True``; the default model path uses the jnp oracles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hh_neuron import hh_step_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def hh_step(v0, m, h, n, g_syn, i_axial, dt, i_ext):
    """Signature-compatible with neuro.cable.hh_soma_update."""
    return hh_step_pallas(v0, m, h, n, g_syn, i_axial, i_ext,
                          dt=float(dt), interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=_interpret())


def ssd_scan(x, dt, a, b_in, c_in, chunk: int):
    return ssd_scan_pallas(x, dt, a, b_in, c_in, chunk,
                           interpret=_interpret())
