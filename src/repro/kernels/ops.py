"""Jitted public wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; on CPU they execute in
interpret mode (the kernel body runs in Python per grid step) — that is
the validation path this container supports.  Model code calls these via
``use_pallas=True``; the default model path uses the jnp oracles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hh_neuron import hh_step_pallas
from repro.kernels.paged_attention import (paged_attention_pallas,
                                           paged_attention_ref)
from repro.kernels.ssd_scan import ssd_scan_pallas

#: Force interpret mode regardless of backend (tests/conftest.py sets
#: this off-accelerator so tier-1 exercises the kernel bodies on CPU CI
#: even if the backend probe ever reports something exotic).
FORCE_INTERPRET = False

#: Force the Pallas paged-attention kernel onto the serving hot path even
#: off-accelerator (it then runs in interpret mode).  Tests use this to
#: drive the kernel through the full engine on CPU; production CPU
#: serving takes the pure-JAX page-table reference instead — same paged
#: pathway, bit-comparable to the contiguous oracle.
FORCE_PAGED_KERNEL = False


def _interpret() -> bool:
    return FORCE_INTERPRET or jax.default_backend() != "tpu"


def use_paged_kernel() -> bool:
    """Whether the serving engine's paged path lowers the Pallas kernel
    (TPU, or forced for tests) vs the pure-JAX page-table reference."""
    return FORCE_PAGED_KERNEL or jax.default_backend() == "tpu"


def hh_step(v0, m, h, n, g_syn, i_axial, dt, i_ext):
    """Signature-compatible with neuro.cable.hh_soma_update."""
    return hh_step_pallas(v0, m, h, n, g_syn, i_axial, i_ext,
                          dt=float(dt), interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=_interpret())


def ssd_scan(x, dt, a, b_in, c_in, chunk: int):
    return ssd_scan_pallas(x, dt, a, b_in, c_in, chunk,
                           interpret=_interpret())


def paged_attention(q, k_pool, v_pool, page_table, pos, n_new):
    """Decode/chunk attention through the device page table (the paged
    serving engine's hot path).  TPU: native Mosaic; CPU: interpret mode
    (the validation pathway this container supports)."""
    return paged_attention_pallas(q, k_pool, v_pool, page_table, pos, n_new,
                                  interpret=_interpret())
