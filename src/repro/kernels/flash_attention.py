"""Pallas TPU kernel: blockwise causal flash attention.

TPU adaptation of the standard flash algorithm (not a CUDA port):
  * grid (batch·heads, q_blocks, k_blocks) with the k dimension
    'arbitrary' (sequential) so the running max/denominator/accumulator
    live in VMEM scratch across k steps;
  * (block_q × head_dim) and (block_k × head_dim) tiles are MXU-aligned
    (128 multiples);
  * causal block-skipping via pl.when — upper-triangle blocks issue no
    MXU work, which is exactly the 2× attention-flop saving the jnp path
    (full-mask) pays; roofline accounting uses this kernel's flop count
    for the optimized variant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  n_k_blocks: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: k block kb is needed iff its first column <= the q block's
    # last row;  last needed block = ((qb+1)·bq − 1) // bk  (block sizes
    # may differ, so compare positions, not block indices)
    last = (((qb + 1) * block_q - 1) // block_k) if causal else n_k_blocks - 1
    run = (kb <= last) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0].astype(jnp.float32)          # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        if causal:
            q_pos = qb * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kb == last)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           scale: float | None = None,
                           interpret: bool = False):
    """q, k, v: [BH, S, D] (kv already broadcast to the q-head count —
    the model layer passes GQA-grouped tensors).  Returns [BH, S, D]."""
    bh, s, d = q.shape
    assert k.shape == v.shape == (bh, s, d), (q.shape, k.shape)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    scale = scale if scale is not None else d ** -0.5

    grid = (bh, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, n_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max
            pltpu.VMEM((block_q,), jnp.float32),   # running denominator
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out
