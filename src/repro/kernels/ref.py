"""Pure-jnp oracles for every Pallas kernel.

These are THE reference semantics: model code uses them by default (the
portable path), kernels must match them (tests/test_kernels.py sweeps
shapes/dtypes with assert_allclose), and the dual-environment harness
(core/verify.py) treats (oracle, kernel) as its two environments —
the repo-level analogue of the paper's native-vs-container comparison.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked
from repro.neuro.cable import hh_soma_update


def hh_step_ref(v0, m, h, n, g_syn, i_axial, i_ext, *, dt: float):
    """Oracle for kernels/hh_neuron.py — delegates to the model's own
    update (single source of truth for the HH math)."""
    f32 = jnp.float32
    return hh_soma_update(
        jnp.asarray(v0, f32), jnp.asarray(m, f32), jnp.asarray(h, f32),
        jnp.asarray(n, f32), jnp.asarray(g_syn, f32),
        jnp.asarray(i_axial, f32), dt, jnp.asarray(i_ext, f32))


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """Oracle for kernels/flash_attention.py: plain softmax attention.
    q, k, v: [BH, S, D]."""
    bh, s, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, a, b_in, c_in, chunk: int):
    """Oracle for kernels/ssd_scan.py — the model's chunked jnp SSD."""
    return ssd_chunked(x, dt, a, b_in, c_in, min(chunk, x.shape[1]))


def ssd_sequential_ref(x, dt, a, b_in, c_in):
    """Second, independent oracle: the O(S·N·P) sequential recurrence the
    SSD algorithm must equal (validates the chunked oracle itself)."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    hg = h // g
    f32 = jnp.float32
    bh = jnp.repeat(b_in.astype(f32), hg, axis=2)   # [B,S,H,N]
    ch = jnp.repeat(c_in.astype(f32), hg, axis=2)
    dtf = dt.astype(f32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,H,N], [B,H,N]
        da = jnp.exp(dtt * a)
        state = (state * da[..., None, None]
                 + (dtt[..., None] * xt)[..., None] * bt[:, :, None, :])
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    state0 = jnp.zeros((bsz, h, p, n), f32)
    xs = (jnp.moveaxis(x.astype(f32), 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bh, 1, 0), jnp.moveaxis(ch, 1, 0))
    final, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
