"""Per-request timeline reconstruction and latency attribution.

The audit stack so far gates *aggregates*: counters, hit rates, and SLO
quantiles.  When a ``pathway-slo`` finding fires they cannot say whether
a request's latency was queue wait, preemption gaps, prefill chunking,
decode pacing, or a routing detour.  This module reads the request
lifecycle back out of the ``Tracer`` event stream —

    submit → [route] → admit → prefill chunks → first-token →
    decode steps → [preempt → readmit → re-prefill ...] → finish/cancel

— and decomposes every request's end-to-end latency into named phases
that **provably sum to the total**:

    ``routing``     front door → router placement (cluster runs only)
    ``queue_wait``  placed/submitted → first admission
    ``prefill``     admission → prompt fully consumed (per segment)
    ``decode``      prompt consumed → preemption or completion
    ``preempted``   eviction → readmission (recompute pays into prefill)

Exactness is by construction: phase boundaries are the engines' synthetic
tick-clock payloads converted to ``fractions.Fraction`` (every float is
an exact binary rational), and the spans telescope — consecutive
boundaries partition ``[arrival, end]`` — so the phase sums equal the
total *in ℚ*, not merely within float rounding.  Shares therefore sum to
exactly 1 for every closed request, which is what lets the benchmarks
ledger them with zero tolerance.

Two consumers sit on top:

- ``attribution`` — which phase dominates the p99-TTFT request, plus
  population shares; feeds the ``ExpectedSignature`` attribution bounds
  (``pathway-attribution`` findings) and the workload-SLO ledger.
- ``to_chrome_trace`` / ``chrome_trace_bytes`` — Chrome-trace-event JSON
  (load in Perfetto / ``chrome://tracing``): one process per replica,
  one thread per slot (waiting phases ride a synthetic ``queue`` track).
  Built purely from tick payloads, so the same seed + trace renders
  byte-identical output (the ``/timeline`` endpoint's determinism bar).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Any, Iterable

from repro.audit.trace import TraceEvent, Tracer

#: Phase taxonomy.  The tuple order is also the deterministic tie-break
#: when two phases hold an equal share (earlier wins).
PHASES = ("routing", "queue_wait", "prefill", "decode", "preempted")

#: Lifecycle kinds that bound phases, with the within-tick ordering the
#: engines guarantee (admission precedes the chunk that may finish the
#: prompt, which precedes the sampled first token, which precedes any
#: same-tick completion).  finish/cancel share a rank: at most one ends
#: a request.
_ORDER = {"submit": 0, "route": 1, "admit": 2, "prefill-done": 3,
          "first-token": 4, "preempt": 5, "finish": 6, "cancel": 6}

#: Synthetic Chrome-trace thread id for off-slot (waiting) spans — real
#: slots are small integers, so the queue track sorts last.
QUEUE_TID = 9999


def _fr(v: Any) -> Fraction:
    """Exact rational from a tick payload (floats are binary rationals,
    so this loses nothing)."""
    return v if isinstance(v, Fraction) else Fraction(v)


@dataclass(frozen=True)
class Span:
    """One contiguous phase interval on the tick clock (exact bounds)."""

    phase: str
    start: Fraction
    end: Fraction
    slot: int | None = None      # occupied slot (prefill/decode spans only)

    @property
    def length(self) -> Fraction:
        return self.end - self.start


@dataclass
class RequestTimeline:
    """One request's reconstructed lifecycle: ordered spans partitioning
    ``[arrival, end]`` plus the labels the exporters and detectors need."""

    rid: int
    arrival: Fraction
    spans: list[Span] = field(default_factory=list)
    end: Fraction | None = None          # finish/cancel tick; None = in flight
    outcome: str = "in-flight"           # finished | cancelled | in-flight
    replica: int | None = None           # from the route event (cluster runs)
    slots: list[int] = field(default_factory=list)   # slot per admission
    first_token: Fraction | None = None
    preemptions: int = 0
    tokens_out: int = 0
    open_phase: str | None = None        # in-flight: phase still running
    open_since: Fraction | None = None

    # ------------------------------------------------------------- totals
    def total(self) -> Fraction | None:
        return None if self.end is None else self.end - self.arrival

    def phases(self) -> dict[str, Fraction]:
        """Exact per-phase time.  For closed requests
        ``sum(phases().values()) == total()`` holds in ℚ."""
        out = {p: Fraction(0) for p in PHASES}
        for s in self.spans:
            out[s.phase] += s.length
        return out

    def shares(self) -> dict[str, Fraction]:
        """Exact phase fractions of the end-to-end latency; sums to
        exactly 1.  Empty for in-flight or zero-latency requests."""
        total = self.total()
        if not total:
            return {}
        return {p: v / total for p, v in self.phases().items()}

    # --------------------------------------------------------------- ttft
    def ttft(self) -> Fraction | None:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    def phases_until(self, t: Fraction) -> dict[str, Fraction]:
        """Exact per-phase time clipped to ``[arrival, t]``."""
        out = {p: Fraction(0) for p in PHASES}
        for s in self.spans:
            hi = min(s.end, t)
            if hi > s.start:
                out[s.phase] += hi - s.start
        return out

    def ttft_phases(self) -> dict[str, Fraction]:
        if self.first_token is None:
            return {}
        return self.phases_until(self.first_token)

    def ttft_shares(self) -> dict[str, Fraction]:
        """Exact phase fractions of TTFT (sums to exactly 1); empty when
        the first token has not landed or TTFT is zero."""
        ttft = self.ttft()
        if not ttft:
            return {}
        return {p: v / ttft for p, v in self.ttft_phases().items()}

    # ------------------------------------------------------------ export
    def describe(self) -> dict:
        """JSON-able summary (floats; the exact rationals stay internal)."""
        out = {
            "rid": self.rid,
            "arrival": float(self.arrival),
            "end": None if self.end is None else float(self.end),
            "outcome": self.outcome,
            "replica": self.replica,
            "slots": list(self.slots),
            "ttft_ticks": (None if self.ttft() is None
                           else float(self.ttft())),
            "preemptions": self.preemptions,
            "tokens_out": self.tokens_out,
            "phases": {p: float(v) for p, v in self.phases().items()},
            "shares": {p: float(v) for p, v in self.shares().items()},
            "spans": [{"phase": s.phase, "start": float(s.start),
                       "end": float(s.end), "slot": s.slot}
                      for s in self.spans],
        }
        if self.end is None and self.open_phase is not None:
            out["open_phase"] = self.open_phase
            out["open_since"] = (None if self.open_since is None
                                 else float(self.open_since))
        return out


# ============================================================ reconstruction


def _records(source: Any) -> Iterable[dict]:
    """Normalise an event source to payload dicts: a ``Tracer``, an
    ``EventLog`` (anything with ``records()``), or an iterable of
    ``TraceEvent``/dict."""
    if isinstance(source, Tracer):
        return (e.to_dict() for e in source.events())
    if hasattr(source, "records"):
        return source.records()
    return (e.to_dict() if isinstance(e, TraceEvent) else e for e in source)


def build_timelines(*sources: Any) -> dict[int, RequestTimeline]:
    """Reconstruct per-request timelines from one or more event sources.

    Cluster runs merge naturally: pass the cluster tracer *and* the
    replica tracers — ``submit``/``route`` events the router mirrors
    into the chosen replica's tracer are deduplicated by (kind, tick),
    and the replica label comes from the ``route`` payload.  Non-
    lifecycle events (``step``, ``sched-*``, ``engine-init``, ...) are
    ignored, so the full ``EventLog`` stream can be fed unseen."""
    by_rid: dict[int, list[dict]] = {}
    for source in sources:
        for rec in _records(source):
            kind = rec.get("kind")
            rid = rec.get("rid")
            if kind not in _ORDER or rid is None:
                continue
            if kind != "submit" and "tick" not in rec:
                continue       # phase boundaries need the tick clock
            by_rid.setdefault(rid, []).append(rec)
    out: dict[int, RequestTimeline] = {}
    for rid in sorted(by_rid):
        ordered = sorted(
            by_rid[rid],
            key=lambda r: (_fr(r.get("tick", r.get("arrival", 0.0))),
                           _ORDER[r["kind"]]))
        tl = _build_one(rid, ordered)
        if tl is not None:
            out[rid] = tl
    return out


def _build_one(rid: int, ordered: list[dict]) -> RequestTimeline | None:
    tl: RequestTimeline | None = None
    state = "queue_wait"
    cur: Fraction | None = None
    seen: set[tuple[str, Fraction]] = set()

    def close(phase: str, t: Fraction, slot: int | None = None) -> None:
        nonlocal cur
        if t > cur:
            tl.spans.append(Span(phase, cur, t, slot=slot))
        cur = t

    for rec in ordered:
        kind = rec["kind"]
        if kind == "submit":
            if tl is None:
                arrival = _fr(rec.get("arrival", rec.get("tick", 0.0)))
                tl = RequestTimeline(rid=rid, arrival=arrival)
                cur = arrival
            continue
        t = _fr(rec["tick"])
        if (kind, t) in seen:
            continue        # cluster-mirrored duplicate (route) or replay
        seen.add((kind, t))
        if tl is None:
            # submit evicted from the bounded ring: the timeline starts
            # at the first retained boundary (a window, not a census)
            tl = RequestTimeline(rid=rid,
                                 arrival=_fr(rec.get("arrival", rec["tick"])))
            cur = tl.arrival
        slot = tl.slots[-1] if tl.slots else None
        if kind == "route":
            close("routing", t)
            state = "queue_wait"
            tl.replica = rec.get("replica")
        elif kind == "admit":
            close(state, t)             # queue_wait or preempted gap
            state = "prefill"
            tl.slots.append(rec.get("slot"))
        elif kind == "prefill-done":
            close("prefill", t, slot=slot)
            state = "decode"
        elif kind == "first-token":
            if tl.first_token is None:
                tl.first_token = t
        elif kind == "preempt":
            close(state, t, slot=slot if state in ("prefill", "decode")
                  else None)
            state = "preempted"
            tl.preemptions += 1
        elif kind in ("finish", "cancel"):
            close(state, t, slot=slot if state in ("prefill", "decode")
                  else None)
            tl.end = t
            tl.outcome = "finished" if kind == "finish" else "cancelled"
            tl.tokens_out = rec.get("tokens_out", tl.tokens_out)
    if tl is not None and tl.end is None:
        tl.open_phase, tl.open_since = state, cur
    return tl


# ============================================================== attribution


def attribution(timelines: dict[int, RequestTimeline],
                q: float = 0.99) -> dict:
    """Aggregate latency attribution over a set of timelines.

    Picks the nearest-rank ``q``-quantile request by TTFT (ties broken
    by rid, so the pick is deterministic) and reports which phase
    dominates *its* first-token latency, alongside population-level
    phase shares of total end-to-end latency.  Everything is computed in
    exact rationals and exported as floats."""
    closed = [tl for tl in timelines.values()
              if tl.end is not None and tl.ttft() is not None]
    if not closed:
        return {}
    ordered = sorted(closed, key=lambda tl: (tl.ttft(), tl.rid))
    worst = ordered[min(math.ceil(q * len(ordered)), len(ordered)) - 1]
    shares = worst.ttft_shares()
    dominant = None
    if shares:
        best = max(shares.values())
        dominant = next(p for p in PHASES if shares[p] == best)

    pop_total = sum((tl.total() for tl in closed), Fraction(0))
    pop_phase = {p: Fraction(0) for p in PHASES}
    for tl in closed:
        for p, v in tl.phases().items():
            pop_phase[p] += v
    pop_shares = ({p: float(v / pop_total) for p, v in pop_phase.items()}
                  if pop_total else {})
    return {
        "requests": len(closed),
        "p99_ttft_ticks": float(worst.ttft()),
        "p99_rid": worst.rid,
        "dominant_phase": dominant,
        "p99_shares": {p: float(v) for p, v in shares.items()},
        "population_shares": pop_shares,
        "preempted_share": pop_shares.get("preempted", 0.0),
    }


# ========================================================== chrome export


def to_chrome_trace(timelines: dict[int, RequestTimeline], *,
                    tick_us: float = 1000.0) -> dict:
    """Chrome-trace-event JSON (Perfetto / ``chrome://tracing``): one
    process per replica (pid = replica index; single-engine runs are
    pid 0), one thread per slot, plus a synthetic ``queue`` thread per
    process carrying the off-slot phases (routing / queue_wait /
    preempted).  One engine tick renders as ``tick_us`` microseconds.

    Deterministic: events are emitted in sorted (rid, span) order from
    exact tick rationals — no wall clock anywhere."""
    events: list[dict] = []
    tracks: set[tuple[int, int]] = set()
    for rid in sorted(timelines):
        tl = timelines[rid]
        pid = tl.replica if tl.replica is not None else 0
        for s in tl.spans:
            tid = s.slot if s.slot is not None else QUEUE_TID
            tracks.add((pid, tid))
            events.append({
                "ph": "X", "cat": "request", "name": s.phase,
                "pid": pid, "tid": tid,
                "ts": float(s.start * _fr(tick_us)),
                "dur": float(s.length * _fr(tick_us)),
                "args": {"rid": tl.rid, "phase": s.phase,
                         "outcome": tl.outcome},
            })
    meta: list[dict] = []
    for pid in sorted({p for p, _ in tracks}):
        meta.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                     "args": {"name": f"replica {pid}"}})
    for pid, tid in sorted(tracks):
        name = "queue" if tid == QUEUE_TID else f"slot {tid}"
        meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                     "tid": tid, "args": {"name": name}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"tick_us": tick_us,
                          "requests": len(timelines)}}


def chrome_trace_bytes(timelines: dict[int, RequestTimeline], *,
                       tick_us: float = 1000.0) -> bytes:
    """The ``/timeline`` body: canonical JSON rendering (sorted keys,
    fixed separators) of ``to_chrome_trace`` — same seed + trace ⇒
    byte-identical output."""
    doc = to_chrome_trace(timelines, tick_us=tick_us)
    return (json.dumps(doc, sort_keys=True,
                       separators=(",", ":")) + "\n").encode()
