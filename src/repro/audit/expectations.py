"""Declarative pathway-expectation registry.

The dual-environment verdict (``core.verify``) proves two pathways give
the same *answer*; it cannot see that one of them took a degraded route —
a dense arch silently falling back to the contiguous engine, a shrunken
page size, a disabled prefix cache, or a hot loop recompiling every tick
all produce token-identical output.  This registry encodes what the hot
path *should* look like for a given (arch family, mesh shape, workload)
and turns runtime evidence (trace events, engine reports,
``inspector.TransportReport``) into diagnostics findings in the existing
severity vocabulary, exactly the paper's "detect suboptimal transport
pathways from debug output" loop (§8) applied to our own runtime.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

from repro.audit.timeline import attribution, build_timelines
from repro.audit.trace import Tracer
from repro.core.inspector import COLLECTIVES, TransportReport


@dataclass(frozen=True)
class AuditContext:
    """What ran: the registry key. ``mesh`` is the device-mesh shape (a
    single-process serving run is ``(1,)``); ``shared_prefix`` declares
    that prompts overlap by at least one cache page, so a working prefix
    cache is an expectation rather than an optimisation — callers must
    leave it False when the common prefix is shorter than the engine's
    block size (sub-block prefixes cannot hit, only full blocks
    register)."""

    workload: str                      # "serve" | "train" | "bench:<name>"
    family: str                        # dense | moe | ssm | hybrid | vlm | encdec
    arch: str = "?"
    mesh: tuple[int, ...] = (1,)
    shared_prefix: bool = False

    @property
    def n_devices(self) -> int:
        return int(math.prod(self.mesh))


@dataclass
class Evidence:
    """What we observed.  Any subset may be present; checks that lack
    their evidence are skipped (absence of evidence is not a finding)."""

    tracer: Tracer | None = None
    engine_report: dict | None = None      # ServeEngine/PagedServeEngine.report()
    transport: TransportReport | None = None
    # cluster runs: replica tracers carry the admit/prefill-done/finish
    # events the cluster tracer never sees; timeline reconstruction
    # merges them (duplicated submit/route events deduplicate)
    replica_tracers: Sequence[Tracer] = ()

    # ------------------------------------------------- derived accessors
    def engine_kind(self) -> str | None:
        if self.tracer is not None:
            ev = self.tracer.last("engine-init")
            if ev is not None:
                return ev.data.get("engine")
        if self.engine_report:
            return self.engine_report.get("engine")
        return None

    def engine_init(self) -> dict | None:
        if self.tracer is not None:
            ev = self.tracer.last("engine-init")
            if ev is not None:
                return ev.data
        return self.engine_report

    def request_latencies(self) -> dict[int, dict]:
        """Per-request lifecycle latencies on the engine tick clock,
        computed from the request-lifecycle trace events.  Returns
        rid -> {``ttft_ticks``, ``decode_gap_ticks`` (mean ticks per
        token after the first; requires a finish event), ``tokens``}.
        Cancelled requests are excluded — a cancelled stream has no
        defined completion latency.

        TTFT is read from the ``first-token`` event's own
        ``ttft_ticks`` payload (engines stamp it at emission), with
        ``tick - submit.arrival`` as a fallback — so the measurement
        survives the bounded ring evicting old ``submit`` events on
        long runs.  Requests whose *first-token* event itself was
        evicted are necessarily absent: the latencies are the retained
        window, not a lifetime census."""
        if self.tracer is None:
            return {}
        arrival: dict[int, float] = {}
        first: dict[int, dict] = {}
        fin: dict[int, dict] = {}
        cancelled: set[int] = set()
        for e in self.tracer.events("submit"):
            if "rid" in e.data:
                arrival[e.data["rid"]] = e.data.get(
                    "arrival", e.data.get("tick", 0.0))
        for e in self.tracer.events("first-token"):
            if "rid" in e.data:
                first.setdefault(e.data["rid"], e.data)
        for e in self.tracer.events("finish"):
            if "rid" in e.data and "tick" in e.data:
                fin[e.data["rid"]] = e.data
        for e in self.tracer.events("cancel"):
            cancelled.add(e.data.get("rid"))
        out: dict[int, dict] = {}
        for rid, ft in first.items():
            if rid in cancelled:
                continue
            if "ttft_ticks" in ft:
                rec = {"ttft_ticks": ft["ttft_ticks"]}
            elif "tick" in ft and rid in arrival:
                rec = {"ttft_ticks": ft["tick"] - arrival[rid]}
            else:
                continue
            f = fin.get(rid)
            if f is not None and "tick" in ft:
                n = f.get("tokens_out", 1)
                rec["decode_gap_ticks"] = ((f["tick"] - ft["tick"])
                                           / max(n - 1, 1))
                rec["tokens"] = n
            out[rid] = rec
        return out

    def request_timelines(self) -> dict:
        """Per-request phase decomposition (``audit.timeline``) rebuilt
        from the lifecycle trace: rid -> ``RequestTimeline`` whose
        ``queue_wait``/``prefill``/``decode``/``preempted``/``routing``
        spans sum exactly to the end-to-end tick latency.  Subject to
        the same retained-window caveat as ``request_latencies``."""
        if self.tracer is None:
            return {}
        return build_timelines(self.tracer, *self.replica_tracers)

    def compile_counts(self) -> dict[str, int]:
        """Per-jitted-function compile (cache-miss) counts.

        Trace events give the per-fn breakdown but live in a bounded
        ring; the engine report's ``compiles`` field is the watcher's
        exact lifetime counter, so it wins when larger (a long run whose
        early compile events were evicted still judges correctly)."""
        counts: dict[str, int] = {}
        if self.tracer is not None:
            for ev in self.tracer.events("compile"):
                fn = ev.data.get("fn", "?")
                counts[fn] = counts.get(fn, 0) + 1
        rep = self.engine_report or {}
        if isinstance(rep.get("compiles"), int):
            if rep.get("engine") in ("paged", "cluster"):
                # a cluster's replicas are paged engines sharing one jit
                # cache; its ``compiles`` is the per-replica max, judged
                # against the same chunk-fn budget
                fn = ("decode_paged_chunk" if rep.get("kernel") == "paged"
                      else "decode_chunk")
            else:
                fn = "decode_step"
            counts[fn] = max(counts.get(fn, 0), rep["compiles"])
        return counts


@dataclass
class ExpectedSignature:
    """The declarative half of a rule: what the evidence must show.
    ``None`` fields are unchecked."""

    engine: str | None = None               # "paged" | "contiguous"
    kernel: str | None = None               # paged engine KV pathway:
                                            # "paged" (through the page
                                            # table) | "gather" (dense
                                            # working-cache fallback)
    min_block_size: int | None = None       # page geometry floor
    min_prefix_hit_rate: float | None = None  # gated on ctx.shared_prefix
    max_compiles_per_fn: int | None = None  # steady state: 1 per program
    # per-request lifecycle latencies (engine tick clock, from the
    # submit/first-token/finish trace events).  Bounds are workload
    # properties — the defaults carry none; benchmarks and launchers
    # register calibrated rules for traces whose latencies they know.
    max_ttft_ticks: float | None = None
    max_decode_gap_ticks: float | None = None
    # population SLOs over the same tick-clock latencies: the worst-case
    # bounds above catch a single pathological request, the quantile
    # bounds catch systemic degradation under load (a misconfigured
    # scheduler inflates the p99 long before it touches the max on a
    # small trace).  Violations are ``pathway-slo`` findings.  Nearest-
    # rank quantiles over deterministic tick latencies: bit-reproducible.
    p99_ttft_ticks: float | None = None
    p99_decode_gap_ticks: float | None = None
    # cluster routing quality (serve.cluster reports): floors on the
    # fraction of affinity opportunities the router converted and on the
    # cluster-wide prefix hit rate.  Misrouting is the canonical
    # token-invisible degradation — every stream stays bit-identical
    # while prefixes a sibling replica already holds are recomputed.
    # Violations are ``pathway-routing`` findings.  Like the latency
    # bounds, the floors are workload properties: benchmarks register
    # rules calibrated from a healthy affinity run.
    min_routed_affinity: float | None = None
    min_shared_hit_rate: float | None = None
    # latency *attribution* bounds (audit.timeline): the SLO checks say
    # a quantile moved, these say *where the time went* — shares of the
    # p99-TTFT request's first-token latency spent queued / prefilling,
    # and the population share of end-to-end latency lost to preemption
    # gaps.  Exact rationals exported as floats; violations are
    # ``pathway-attribution`` findings naming the dominant phase.
    max_queue_share_p99: float | None = None
    max_prefill_share_p99: float | None = None
    max_preempted_share: float | None = None
    # KV memory tiering (paged engine reports): floor on the fraction of
    # previously-computed rows that readmissions restored from the host
    # swap tier instead of re-prefilling, and a ceiling on the re-
    # prefilled rows themselves.  A disabled/broken swap tier is another
    # token-invisible degradation — streams stay bit-identical while
    # every preemption's work is recomputed.  Violations are
    # ``pathway-tiering`` findings.  The floor is judged only when the
    # run actually readmitted previously-computed work (restored +
    # recompute > 0); an uncontended run is vacuously healthy.
    min_swap_restore_rate: float | None = None
    max_recompute_tokens: int | None = None
    allowed_collectives: frozenset[str] | None = None
    max_collective_group: int | None = None  # default: ctx.n_devices
    forbid_host_transfer: bool = False


@dataclass
class Rule:
    """Registry entry: match predicate (families × workloads × mesh) plus
    the expected signature.  ``families``/``workloads`` of ``None`` match
    anything; mesh bounds are on total device count."""

    name: str
    expect: ExpectedSignature
    families: tuple[str, ...] | None = None
    workloads: tuple[str, ...] | None = None
    min_devices: int = 1
    max_devices: int | None = None
    severity: str = "error"

    def applies(self, ctx: AuditContext) -> bool:
        if self.families is not None and ctx.family not in self.families:
            return False
        if self.workloads is not None:
            base = ctx.workload.split(":", 1)[0]
            if ctx.workload not in self.workloads and base not in self.workloads:
                return False
        n = ctx.n_devices
        if n < self.min_devices:
            return False
        if self.max_devices is not None and n > self.max_devices:
            return False
        return True


class ExpectationRegistry:
    def __init__(self, rules: Sequence[Rule] = ()):
        self.rules: list[Rule] = list(rules)

    def register(self, rule: Rule) -> Rule:
        self.rules.append(rule)
        return rule

    def match(self, ctx: AuditContext) -> list[Rule]:
        return [r for r in self.rules if r.applies(ctx)]

    # ----------------------------------------------------------- evaluate
    def evaluate(self, ctx: AuditContext, ev: Evidence) -> list[dict]:
        findings: list[dict] = []
        for rule in self.match(ctx):
            findings.extend(_check_rule(rule, ctx, ev))
        return findings


def nearest_rank(values: Sequence[float], q: float) -> float:
    """Deterministic nearest-rank quantile (the ceil(q*n)-th order
    statistic) — no interpolation, so SLO judgements over tick-clock
    latencies are bit-reproducible across platforms."""
    if not values:
        raise ValueError("quantile of an empty population")
    if not 0.0 < q <= 1.0:
        raise ValueError(f"quantile must be in (0, 1], got {q}")
    ordered = sorted(values)
    return ordered[min(math.ceil(q * len(ordered)), len(ordered)) - 1]


def _find(rule: Rule, kind: str, detail: str) -> dict:
    return {"severity": rule.severity, "kind": kind,
            "detail": f"[{rule.name}] {detail}"}


def _check_rule(rule: Rule, ctx: AuditContext, ev: Evidence) -> list[dict]:
    out: list[dict] = []
    sig = rule.expect

    if sig.engine is not None:
        got = ev.engine_kind()
        if got == "cluster" and sig.engine != "cluster":
            # a cluster is a router over per-replica engines: rules about
            # the serving pathway judge what each replica runs, read from
            # the cluster's declared replica engine
            init_ = ev.engine_init() or {}
            got = init_.get("replica_engine", got)
        if got is not None and got != sig.engine:
            out.append(_find(
                rule, "pathway-engine-selection",
                f"{ctx.family}/{ctx.workload} served by {got!r} engine; "
                f"expected {sig.engine!r} (token-identical output but a "
                f"degraded transport pathway)"))

    init = ev.engine_init()
    if sig.kernel is not None and init is not None:
        kern = init.get("kernel")
        # absent on contiguous evidence (the engine-selection check above
        # already covers that class); judged only where the field exists
        if kern is not None and kern != sig.kernel:
            out.append(_find(
                rule, "pathway-kernel",
                f"paged serving attends KV via the {kern!r} pathway; "
                f"expected {sig.kernel!r} — the dense per-slot gather "
                f"keeps token streams identical while reintroducing the "
                f"contiguous-shaped copy the page-table kernel removes"))

    if sig.min_block_size is not None and init is not None:
        bs = init.get("block_size")
        if bs is not None and bs < sig.min_block_size:
            out.append(_find(
                rule, "pathway-page-geometry",
                f"page size {bs} below floor {sig.min_block_size}: per-page "
                f"overhead dominates and prefix sharing granularity degrades"))

    if (sig.min_prefix_hit_rate is not None and ctx.shared_prefix
            and init is not None):
        if init.get("prefix_cache") is False:
            out.append(_find(
                rule, "pathway-prefix-cache",
                "prefix cache disabled on a shared-prefix workload: every "
                "admission recomputes the common prefix"))
        else:
            hr = (ev.engine_report or {}).get("prefix_hit_rate")
            if hr is not None and hr < sig.min_prefix_hit_rate:
                out.append(_find(
                    rule, "pathway-prefix-cache",
                    f"prefix hit rate {hr:.3f} below "
                    f"{sig.min_prefix_hit_rate:.3f} on a shared-prefix "
                    f"workload: cache ineffective (mis-sized pages or "
                    f"broken registration)"))

    if sig.max_ttft_ticks is not None or sig.max_decode_gap_ticks is not None:
        lat = ev.request_latencies()
        if lat:
            if sig.max_ttft_ticks is not None:
                rid, worst = max(((r, l["ttft_ticks"]) for r, l in lat.items()),
                                 key=lambda x: x[1])
                if worst > sig.max_ttft_ticks:
                    out.append(_find(
                        rule, "pathway-ttft",
                        f"request {rid} first token after {worst:.1f} ticks "
                        f"(> {sig.max_ttft_ticks:.1f}): admission latency "
                        f"degraded (output streams stay identical, the "
                        f"route to them slowed)"))
            if sig.max_decode_gap_ticks is not None:
                gaps = [(r, l["decode_gap_ticks"]) for r, l in lat.items()
                        if "decode_gap_ticks" in l]
                if gaps:
                    rid, worst = max(gaps, key=lambda x: x[1])
                    if worst > sig.max_decode_gap_ticks:
                        out.append(_find(
                            rule, "pathway-decode-latency",
                            f"request {rid} averaged {worst:.2f} ticks per "
                            f"decoded token (> {sig.max_decode_gap_ticks:.2f})"))

    if sig.p99_ttft_ticks is not None or sig.p99_decode_gap_ticks is not None:
        lat = ev.request_latencies()
        if lat:
            if sig.p99_ttft_ticks is not None:
                p99 = nearest_rank(
                    [l["ttft_ticks"] for l in lat.values()], 0.99)
                if p99 > sig.p99_ttft_ticks:
                    out.append(_find(
                        rule, "pathway-slo",
                        f"p99 TTFT {p99:.2f} ticks over {len(lat)} "
                        f"request(s) breaches the "
                        f"{sig.p99_ttft_ticks:.2f}-tick SLO: the serving "
                        f"pathway degrades under this load (streams stay "
                        f"identical; the tail latency does not)"))
            if sig.p99_decode_gap_ticks is not None:
                gaps = [l["decode_gap_ticks"] for l in lat.values()
                        if "decode_gap_ticks" in l]
                if gaps:
                    p99 = nearest_rank(gaps, 0.99)
                    if p99 > sig.p99_decode_gap_ticks:
                        out.append(_find(
                            rule, "pathway-slo",
                            f"p99 inter-token gap {p99:.2f} ticks breaches "
                            f"the {sig.p99_decode_gap_ticks:.2f}-tick SLO "
                            f"({len(gaps)} finished request(s))"))

    if (sig.max_queue_share_p99 is not None
            or sig.max_prefill_share_p99 is not None
            or sig.max_preempted_share is not None):
        att = attribution(ev.request_timelines())
        shares = att.get("p99_shares", {}) if att else {}
        if shares:
            dom = att["dominant_phase"]
            where = (f"dominant phase: {dom} "
                     f"({shares.get(dom, 0.0):.0%} of the "
                     f"{att['p99_ttft_ticks']:.1f}-tick p99 TTFT, "
                     f"request {att['p99_rid']})")
            if (sig.max_queue_share_p99 is not None
                    and shares.get("queue_wait", 0.0)
                    > sig.max_queue_share_p99):
                out.append(_find(
                    rule, "pathway-attribution",
                    f"queue_wait holds {shares['queue_wait']:.0%} of the "
                    f"p99-TTFT request's latency "
                    f"(> {sig.max_queue_share_p99:.0%}); {where} — "
                    f"admission, not compute, is the bottleneck (token "
                    f"streams stay identical)"))
            if (sig.max_prefill_share_p99 is not None
                    and shares.get("prefill", 0.0)
                    > sig.max_prefill_share_p99):
                out.append(_find(
                    rule, "pathway-attribution",
                    f"prefill holds {shares['prefill']:.0%} of the "
                    f"p99-TTFT request's latency "
                    f"(> {sig.max_prefill_share_p99:.0%}); {where} — "
                    f"prompt processing dominates the tail (chunking or "
                    f"prefix-cache pathway degraded)"))
        if (att and sig.max_preempted_share is not None
                and att["preempted_share"] > sig.max_preempted_share):
            out.append(_find(
                rule, "pathway-attribution",
                f"preemption gaps hold {att['preempted_share']:.0%} of "
                f"total end-to-end latency across {att['requests']} "
                f"request(s) (> {sig.max_preempted_share:.0%}): the "
                f"scheduler is thrashing admitted work"))

    rep = ev.engine_report or {}
    if sig.min_routed_affinity is not None:
        ra = rep.get("routed_affinity")
        # vacuously healthy when the workload offered no affinity
        # opportunity — nothing to convert, nothing to misroute
        if (ra is not None and rep.get("affine_opportunities", 0) > 0
                and ra < sig.min_routed_affinity):
            out.append(_find(
                rule, "pathway-routing",
                f"router converted {ra:.3f} of "
                f"{rep['affine_opportunities']} affinity opportunities "
                f"(< {sig.min_routed_affinity:.3f}): requests land off "
                f"their prefix-affine replica (token streams stay "
                f"identical; resident prefixes are recomputed)"))
    if sig.min_shared_hit_rate is not None and ctx.shared_prefix:
        shr = rep.get("shared_hit_rate")
        if shr is not None and shr < sig.min_shared_hit_rate:
            out.append(_find(
                rule, "pathway-routing",
                f"cluster-wide prefix hit rate {shr:.3f} below "
                f"{sig.min_shared_hit_rate:.3f} on a shared-prefix "
                f"workload: misrouting scatters prefix-sharing requests "
                f"across replicas, recomputing pages a sibling holds"))

    if sig.min_swap_restore_rate is not None:
        srr = rep.get("swap_restore_rate")
        readmitted = (rep.get("restored_tokens", 0)
                      + rep.get("recompute_tokens", 0))
        if srr is not None and readmitted > 0 and srr < sig.min_swap_restore_rate:
            out.append(_find(
                rule, "pathway-tiering",
                f"readmissions restored only {srr:.0%} of "
                f"{readmitted} previously-computed KV rows from the host "
                f"swap tier (< {sig.min_swap_restore_rate:.0%}): preempted "
                f"work is re-prefilled instead of swapped back in (token "
                f"streams stay identical; the memory pathway degraded)"))
    if sig.max_recompute_tokens is not None:
        rt = rep.get("recompute_tokens")
        if rt is not None and rt > sig.max_recompute_tokens:
            out.append(_find(
                rule, "pathway-tiering",
                f"{rt} previously-computed KV rows re-prefilled on "
                f"readmission (> {sig.max_recompute_tokens}): the host "
                f"swap tier is absorbing less preempted work than this "
                f"trace's healthy baseline"))

    if sig.max_compiles_per_fn is not None:
        for fn, n in ev.compile_counts().items():
            if n > sig.max_compiles_per_fn:
                out.append(_find(
                    rule, "pathway-recompilation",
                    f"{fn} compiled {n}× (> {sig.max_compiles_per_fn}): "
                    f"shape polymorphism leaked into the hot loop"))

    if ev.transport is not None:
        if sig.allowed_collectives is not None:
            bad = set(ev.transport.counts()) - set(sig.allowed_collectives)
            if bad:
                out.append(_find(
                    rule, "pathway-collective-kind",
                    f"unexpected collective kind(s) {sorted(bad)}; expected "
                    f"subset of {sorted(sig.allowed_collectives)}"))
        max_group = sig.max_collective_group
        if max_group is None and (sig.allowed_collectives is not None
                                  or sig.forbid_host_transfer):
            max_group = ctx.n_devices
        if max_group is not None:
            for op in ev.transport.ops:
                if op.group_size > max_group:
                    out.append(_find(
                        rule, "pathway-collective-group",
                        f"{op.name}: {op.kind} over group of "
                        f"{op.group_size} > mesh bound {max_group}"))
                    break
        if sig.forbid_host_transfer:
            for f in ev.transport.findings:
                if f.get("kind") == "host-transfer":
                    out.append(_find(
                        rule, "pathway-host-transfer",
                        "host transfer (infeed/outfeed/send/recv) on the "
                        "hot path: " + f.get("detail", "")))
                    break
    return out


# ===================================================== default expectations

#: Serving on attention-cache families must take the paged path — engine
#: AND kernel: KV attended through the device page table, not gathered
#: into a dense per-slot working cache — with sane page geometry, an
#: effective prefix cache on shared-prefix traces, and exactly one
#: compile per jitted program (fixed shapes).
_SERVE_PAGED = Rule(
    name="serve-dense-paged",
    families=("dense", "moe"),
    workloads=("serve", "bench"),
    expect=ExpectedSignature(
        engine="paged",
        kernel="paged",
        min_block_size=4,
        min_prefix_hit_rate=0.05,
        max_compiles_per_fn=1,
    ),
)

#: Stateful-cache families have no chunked path yet: contiguous is the
#: *correct* pathway for them (flagging paged here catches the inverse
#: misconfiguration once a paged path exists for ssm/hybrid).
_SERVE_STATEFUL = Rule(
    name="serve-stateful-contiguous",
    families=("ssm", "hybrid", "vlm", "encdec"),
    workloads=("serve", "bench"),
    expect=ExpectedSignature(engine="contiguous", max_compiles_per_fn=1),
)

#: Training hot paths: collective group sizes bounded by the mesh, no
#: host transfers inside the compiled step.
_TRAIN_TRANSPORT = Rule(
    name="train-transport",
    workloads=("train",),
    expect=ExpectedSignature(forbid_host_transfer=True),
)

#: all-to-all is expert dispatch: a non-moe train step emitting one took
#: a wrong partitioning pathway (e.g. a resharding the rule set should
#: have expressed as gather/scatter).
_TRAIN_NO_DISPATCH = Rule(
    name="train-no-expert-dispatch",
    families=tuple(f for f in ("dense", "ssm", "hybrid", "vlm", "encdec")),
    workloads=("train",),
    expect=ExpectedSignature(
        allowed_collectives=frozenset(
            k for k in COLLECTIVES
            if k not in ("all-to-all", "ragged-all-to-all")),
    ),
)

DEFAULT_REGISTRY = ExpectationRegistry(
    [_SERVE_PAGED, _SERVE_STATEFUL, _TRAIN_TRANSPORT, _TRAIN_NO_DISPATCH])
