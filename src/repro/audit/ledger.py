"""Persisted perf ledger: per-benchmark baselines with regression gates.

One ``BENCH_<name>.json`` file per benchmark (the repo's benchmark
artifact convention) holding the baseline metric values plus a bounded
history of runs.  Semantics:

  * first run on a site writes the baseline (an ``info`` finding records
    that no comparison happened);
  * later runs compare each gated metric against the baseline with a
    per-metric relative threshold and direction (throughput regressing
    ≥20% is an ``error``; latency metrics invert the sign);
  * noisy wall-clock metrics can be recorded ungated (``gate=False``) so
    the trajectory is tracked without flaking CI — deterministic
    counters (decode steps, cached tokens, hit rates) carry the tight
    thresholds instead.

This is the BENCH trajectory ROADMAP asks for: the ledger files live
next to the repo (gitignored) on dev machines and in the artifact store
on CI, so "performance-verified" means verified against *this site's*
own history, the paper's per-site attestation model.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Sequence

LEDGER_VERSION = 1
HISTORY_KEEP = 50


@dataclass(frozen=True)
class MetricSpec:
    """How one metric is judged.  ``rel_tol`` is the allowed relative
    move in the *bad* direction (0.2 = 20%); ``higher_is_better`` sets
    which direction is bad; ``gate=False`` records without judging."""

    name: str
    higher_is_better: bool = True
    rel_tol: float = 0.2
    gate: bool = True


@dataclass
class LedgerResult:
    bench: str
    baseline_written: bool = False
    deltas: dict = field(default_factory=dict)    # metric -> delta record
    findings: list[dict] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not any(f["severity"] == "error" for f in self.findings)


class Ledger:
    """Baseline store rooted at a directory of ``BENCH_*.json`` files."""

    def __init__(self, root: str | Path):
        self.root = Path(root)

    def path(self, bench: str) -> Path:
        safe = "".join(c if (c.isalnum() or c in "-_") else "_"
                       for c in bench)
        return self.root / f"BENCH_{safe}.json"

    # ------------------------------------------------------------- state
    def load(self, bench: str) -> dict | None:
        p = self.path(bench)
        if not p.exists():
            return None
        try:
            return json.loads(p.read_text())
        except (json.JSONDecodeError, OSError):
            return None

    def baseline(self, bench: str) -> dict[str, float] | None:
        rec = self.load(bench)
        return rec.get("baseline") if rec else None

    def _write(self, bench: str, rec: dict) -> None:
        self.root.mkdir(parents=True, exist_ok=True)
        self.path(bench).write_text(json.dumps(rec, indent=1, sort_keys=True))

    # --------------------------------------------------------- integrity
    def scan(self) -> list[Path]:
        """Every ``BENCH_*.json`` under the root, sorted by name."""
        return sorted(self.root.glob("BENCH_*.json"))

    def audit_owned(self, owned: Sequence[str]) -> list[dict]:
        """Flag ledger files no registered benchmark owns.

        A ``BENCH_*.json`` whose ``bench`` name (the record field, or the
        filename stem for unparseable files) is not in ``owned`` is a
        stale artifact: either its benchmark was deleted without its
        ledger, or the file was written by code that never landed.
        Orphans are ``error`` findings — a baseline nobody maintains is
        worse than none, because it silently attests metrics nothing
        measures anymore."""
        owned_set = set(owned)
        out: list[dict] = []
        for p in self.scan():
            rec = None
            try:
                rec = json.loads(p.read_text())
            except (json.JSONDecodeError, OSError):
                pass
            name = (rec or {}).get("bench") or p.stem[len("BENCH_"):]
            if name not in owned_set:
                out.append({
                    "severity": "error", "kind": "ledger-orphan",
                    "detail": f"{p.name}: ledger for {name!r} has no "
                              f"registered benchmark owner (known: "
                              f"{sorted(owned_set)}); delete the file or "
                              f"register the benchmark",
                })
        return out

    def rolling_median(self, bench: str, metric: str,
                       window: int = 9) -> dict | None:
        """Median of ``metric`` over the last ``window`` history entries.

        Noisy wall-clock metrics (tracked ungated) are unreadable run to
        run on a shared machine; the rolling median over ledger history
        is the trajectory signal.  Returns ``{median, n, latest}`` or
        ``None`` when no history entry carries the metric."""
        rec = self.load(bench)
        if not rec:
            return None
        vals = [h["metrics"][metric] for h in rec.get("history", [])
                if metric in h.get("metrics", {})][-window:]
        if not vals:
            return None
        ordered = sorted(vals)
        mid = len(ordered) // 2
        med = (ordered[mid] if len(ordered) % 2
               else (ordered[mid - 1] + ordered[mid]) / 2.0)
        return {"median": round(med, 4), "n": len(vals),
                "latest": vals[-1]}

    # ----------------------------------------------------------- compare
    def compare(self, bench: str, metrics: dict[str, float],
                specs: Sequence[MetricSpec], *,
                update_baseline: bool = False) -> LedgerResult:
        """Judge ``metrics`` against the stored baseline and append to the
        run history.  Missing baseline (or ``update_baseline=True``)
        (re)writes it.  Metrics absent from the baseline are added to it
        without judgement (new metrics must not fail old ledgers)."""
        res = LedgerResult(bench=bench)
        rec = self.load(bench) or {
            "version": LEDGER_VERSION, "bench": bench,
            "baseline": None, "history": [],
        }
        by_name = {s.name: s for s in specs}
        base = rec.get("baseline")

        if base is None or update_baseline:
            rec["baseline"] = dict(metrics)
            res.baseline_written = True
            res.findings.append({
                "severity": "info", "kind": "ledger-baseline",
                "detail": f"{bench}: baseline "
                          f"{'rewritten' if base is not None else 'written'} "
                          f"({len(metrics)} metric(s)); no comparison run",
            })
        else:
            for name, cur in metrics.items():
                spec = by_name.get(name, MetricSpec(name, gate=False))
                if name not in base:
                    base[name] = cur     # adopt new metrics silently
                    continue
                ref = base[name]
                # zero baseline: judge against the current value instead
                # so a move away from 0 still registers (a 0-baseline must
                # not blind the gate forever)
                denom = abs(ref) if ref else max(abs(cur), 1e-12)
                rel = (cur - ref) / denom
                # loss = relative move in the bad direction (positive=worse)
                loss = -rel if spec.higher_is_better else rel
                status = "ok"
                if spec.gate and loss > spec.rel_tol:
                    status = "regression"
                    res.findings.append({
                        "severity": "error", "kind": "perf-regression",
                        "detail": f"{bench}.{name}: {cur:g} vs baseline "
                                  f"{ref:g} ({100 * rel:+.1f}%, tolerance "
                                  f"{100 * spec.rel_tol:.0f}% "
                                  f"{'drop' if spec.higher_is_better else 'rise'})",
                    })
                elif spec.gate and -loss > spec.rel_tol:
                    status = "improvement"
                    res.findings.append({
                        "severity": "info", "kind": "perf-improvement",
                        "detail": f"{bench}.{name}: {cur:g} vs baseline "
                                  f"{ref:g} ({100 * rel:+.1f}%) — consider "
                                  f"--update-baseline to ratchet",
                    })
                res.deltas[name] = {
                    "baseline": ref, "current": cur,
                    "rel_change": round(rel, 4), "status": status,
                    "gated": spec.gate,
                }

        rec["history"] = (rec.get("history", [])
                          + [{"t": time.time(), "metrics": dict(metrics)}]
                          )[-HISTORY_KEEP:]
        self._write(bench, rec)
        return res
