"""Fold runtime audit evidence into the CI diagnostics gate.

``RunAudit`` is the one object a launcher or benchmark needs: it owns
the tracer the instrumented layers emit into, evaluates the expectation
registry over the collected evidence, runs the perf ledger comparison,
and folds everything into a ``core.diagnostics.Diagnostics`` whose
``gate()`` drives the process exit code.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from repro.audit.expectations import (DEFAULT_REGISTRY, AuditContext,
                                      Evidence, ExpectationRegistry)
from repro.audit.ledger import Ledger, LedgerResult, MetricSpec
from repro.audit.trace import Tracer
from repro.core.diagnostics import Diagnostics
from repro.core.inspector import TransportReport


@dataclass
class RunAudit:
    """One audited run: create it with the workload context, hand
    ``tracer`` to the engine/scheduler/launcher, then call ``finish``."""

    ctx: AuditContext
    # a fresh copy of the default rules per audit: register() on one
    # RunAudit's registry must not leak into every later audit in the
    # process
    registry: ExpectationRegistry = field(
        default_factory=lambda: ExpectationRegistry(DEFAULT_REGISTRY.rules))
    capacity: int = 4096
    tracer: Tracer = field(init=False)
    last_ledger: LedgerResult | None = field(default=None, init=False)

    def __post_init__(self):
        self.tracer = Tracer(capacity=self.capacity)

    # ---------------------------------------------------------- evaluate
    def evaluate(self, *, engine_report: dict | None = None,
                 transport: TransportReport | None = None) -> list[dict]:
        """Expectation mismatches only (no ledger), as raw findings."""
        ev = Evidence(tracer=self.tracer, engine_report=engine_report,
                      transport=transport)
        return self.registry.evaluate(self.ctx, ev)

    def finish(self, diag: Diagnostics | None = None, *,
               engine_report: dict | None = None,
               transport: TransportReport | None = None,
               ledger: Ledger | None = None,
               bench: str | None = None,
               metrics: dict[str, float] | None = None,
               specs: Sequence[MetricSpec] = (),
               update_baseline: bool = False,
               source: str = "audit") -> Diagnostics:
        """Evaluate expectations (+ ledger when given) into ``diag``."""
        diag = diag or Diagnostics()
        diag.extend(self.evaluate(engine_report=engine_report,
                                  transport=transport), source=source)
        if ledger is not None and bench is not None and metrics:
            self.last_ledger = ledger.compare(
                bench, metrics, specs, update_baseline=update_baseline)
            diag.extend(self.last_ledger.findings, source=f"{source}-ledger")
        return diag

    # ----------------------------------------------------------- summary
    def summary(self, diag: Diagnostics | None = None) -> dict:
        out = {
            "context": {
                "workload": self.ctx.workload, "family": self.ctx.family,
                "arch": self.ctx.arch, "mesh": list(self.ctx.mesh),
                "shared_prefix": self.ctx.shared_prefix,
            },
            "trace": self.tracer.summary(),
            "rules_matched": [r.name for r in self.registry.match(self.ctx)],
        }
        if self.last_ledger is not None:
            out["ledger"] = {
                "bench": self.last_ledger.bench,
                "baseline_written": self.last_ledger.baseline_written,
                "deltas": self.last_ledger.deltas,
            }
        if diag is not None:
            out["findings"] = diag.findings
            out["worst"] = diag.worst
            out["gate_ok"] = diag.gate()
        return out
