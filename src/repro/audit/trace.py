"""Structured runtime event tracer: bounded ring buffer + scoped spans.

Hot paths (engine ticks, scheduler decisions, jitted-step dispatch) emit
small dict-payload events; the buffer is a fixed-capacity ring so a
long-running server pays O(1) per event and bounded memory, while
per-kind counters survive ring overflow so expectation checks see exact
totals even when old events have been dropped.

``NULL_TRACER`` is a shared do-nothing instance: instrumented code holds
an unconditional ``tracer.emit(...)`` call and the disabled path costs
one attribute lookup + empty call — no ``if tracer:`` branches sprinkled
through engines.

Subscribers (``tracer.subscribe``) observe every event *at emission*,
before the ring can drop it — the live-metrics layer
(``audit.metrics``) is built on this: histograms and counters stay
exact on long runs whose early events the bounded ring has already
evicted.
"""
from __future__ import annotations

import time
from collections import Counter, deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

#: Every event kind the instrumented layers may emit.  The emit-kind
#: lint (tests/test_audit.py) greps ``tracer.emit("...")`` /
#: ``tracer.span("...")`` literals out of ``src/``, ``benchmarks/``,
#: and ``scripts/`` and asserts
#: they all appear here, so the metrics layer and the expectation
#: registry can never silently miss a pathway because someone added an
#: emitter without declaring its kind.
KNOWN_KINDS = frozenset({
    # serve.engine — request lifecycle + hot loop (both engines)
    "engine-init", "submit", "admit", "prefill-done", "first-token", "step",
    "preempt", "finish", "cancel", "compile",
    # serve.engine — KV memory tiering (device pool <-> host swap tier):
    # preempt/readmit page parking and cold-prefix spill/page-in
    "swap-out", "swap-in",
    # serve.scheduler — planning decisions
    "sched-admit", "sched-readmit", "sched-preempt", "sched-done",
    "sched-cancel",
    # serve.cluster — multi-replica routing decisions
    "route",
    # launch.train — training loop + checkpointing
    "train-step", "ckpt-save", "ckpt-restore",
    # launch.dryrun — lowering/compile attestation cells
    "dryrun-lower", "dryrun-compile", "dryrun-error",
})


@dataclass
class TraceEvent:
    seq: int                      # monotonic per-tracer event index
    t: float                      # tracer clock at emission
    kind: str
    data: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"seq": self.seq, "t": self.t, "kind": self.kind, **self.data}


class Tracer:
    """Bounded event recorder with exact per-kind counts.

    ``clock`` is injectable (engines pass their synthetic tick clock) so
    traces replay deterministically in tests; default is wall time.
    """

    enabled = True

    def __init__(self, capacity: int = 4096,
                 clock: Callable[[], float] | None = None):
        self.capacity = capacity
        self.clock = clock or time.perf_counter
        self._ring: deque[TraceEvent] = deque(maxlen=capacity)
        self._counts: Counter[str] = Counter()
        self._seq = 0
        self._subs: list[Callable[[TraceEvent], None]] = []

    # ------------------------------------------------------------- record
    def emit(self, kind: str, /, **data: Any) -> None:
        # kind is positional-only so a payload may carry its own "kind"
        ev = TraceEvent(self._seq, self.clock(), kind, data)
        self._ring.append(ev)
        self._counts[kind] += 1
        self._seq += 1
        for sub in self._subs:
            sub(ev)

    # --------------------------------------------------------- subscribe
    def subscribe(self, fn: Callable[[TraceEvent], None]) -> Callable:
        """Register a live observer called with every event at emission —
        *before* the bounded ring can evict it, so a subscriber sees the
        complete stream even when the ring has wrapped (the metrics
        layer's feed contract).  Returns ``fn`` so callers can hold it
        for ``unsubscribe``."""
        self._subs.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[TraceEvent], None]) -> None:
        self._subs.remove(fn)

    @contextmanager
    def span(self, kind: str, /, **data: Any) -> Iterator[dict]:
        """Scoped span: emits ``kind`` once on exit carrying both the
        entry clock reading (``t_start``) and the measured duration
        (``dt_s``), so span trees reconstruct without inferring starts.
        Reads the tracer's injected ``clock`` — under a synthetic tick
        clock the payload is deterministic.  The yielded dict lets the
        body attach results (e.g. a loss value) to the closing event;
        body keys override span kwargs on collision, and
        ``t_start``/``dt_s`` always win."""
        t0 = self.clock()
        extra: dict = {}
        try:
            yield extra
        finally:
            self.emit(kind, **{**data, **extra, "t_start": t0,
                               "dt_s": self.clock() - t0})

    # -------------------------------------------------------------- query
    def events(self, kind: str | None = None) -> list[TraceEvent]:
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e.kind == kind]

    def last(self, kind: str) -> TraceEvent | None:
        for e in reversed(self._ring):
            if e.kind == kind:
                return e
        return None

    def count(self, kind: str) -> int:
        """Exact lifetime count for ``kind`` (survives ring overflow)."""
        return self._counts[kind]

    @property
    def emitted(self) -> int:
        return self._seq

    @property
    def dropped(self) -> int:
        return self._seq - len(self._ring)

    def summary(self) -> dict:
        return {
            "emitted": self.emitted,
            "retained": len(self._ring),
            "dropped": self.dropped,
            "counts": dict(self._counts),
        }


class _NullTracer(Tracer):
    """Do-nothing tracer: instrumentation points call it unconditionally."""

    enabled = False

    def __init__(self):
        super().__init__(capacity=1)

    def emit(self, kind: str, /, **data: Any) -> None:  # noqa: ARG002
        pass

    @contextmanager
    def span(self, kind: str, /, **data: Any) -> Iterator[dict]:  # noqa: ARG002
        yield {}


NULL_TRACER = _NullTracer()
