"""Runtime audit pipeline: trace what the hot paths *did*, check it
against what they *should have done* on this (arch, mesh, workload), and
track performance across PRs.

The paper's outlook (§8) asks for automated debug-log parsing that
detects suboptimal transport pathways without user intervention.  The
inspector already does that for compiled HLO; this package extends the
idea to runtime behaviour:

  trace        — low-overhead structured event tracer (ring buffer +
                 scoped spans) the serving engines, scheduler, decode
                 step, and launchers emit into
  expectations — declarative pathway-expectation registry mapping
                 (arch family, mesh shape, workload) → expected
                 signatures; mismatches become diagnostics findings
  ledger       — persisted per-benchmark perf ledger (``BENCH_*.json``)
                 with baseline load/compare/update semantics, regression
                 thresholds, orphan-file integrity auditing, and
                 rolling-median trend extraction over run history
  metrics      — live serving observability: a Tracer-fed
                 ``MetricsRegistry`` (counters / gauges / fixed-bucket
                 histograms on the tick clock), a queryable ``EventLog``
                 (JSONL export, filter by kind/rid/tick window), and the
                 ``MetricsServer`` HTTP exposition (``/metrics``,
                 ``/metrics.json``, ``/healthz``, ``/events``,
                 ``/timeline``, ``/requests/<rid>``)
  timeline     — per-request span-tree reconstruction from the lifecycle
                 trace: exact phase decomposition (queue_wait / prefill /
                 decode / preempted / routing sums to the total in ℚ),
                 p99-TTFT attribution, and a Chrome-trace (Perfetto)
                 exporter
  report       — folds traces + expectation mismatches + ledger
                 regressions into ``core.diagnostics.Diagnostics`` so
                 CI gates on them
"""
from repro.audit.expectations import (DEFAULT_REGISTRY, AuditContext,
                                      Evidence, ExpectationRegistry,
                                      ExpectedSignature, Rule, nearest_rank)
from repro.audit.ledger import Ledger, LedgerResult, MetricSpec
from repro.audit.metrics import (EventLog, MetricsRegistry, MetricsServer,
                                 ServeMetrics, query_jsonl)
from repro.audit.report import RunAudit
from repro.audit.timeline import (PHASES, RequestTimeline, Span, attribution,
                                  build_timelines, chrome_trace_bytes,
                                  to_chrome_trace)
from repro.audit.trace import KNOWN_KINDS, NULL_TRACER, TraceEvent, Tracer

__all__ = [
    "AuditContext", "DEFAULT_REGISTRY", "EventLog", "Evidence",
    "ExpectationRegistry", "ExpectedSignature", "KNOWN_KINDS", "Ledger",
    "LedgerResult", "MetricSpec", "MetricsRegistry", "MetricsServer",
    "NULL_TRACER", "PHASES", "RequestTimeline", "Rule", "RunAudit",
    "ServeMetrics", "Span", "TraceEvent", "Tracer", "attribution",
    "build_timelines", "chrome_trace_bytes", "nearest_rank", "query_jsonl",
    "to_chrome_trace",
]
