"""Live serving metrics: Tracer-fed registry, HTTP exposition, log export.

The audit pipeline so far is a *batch* artifact — traces and ledgers are
judged after a run ends.  This module makes the same evidence available
while the server is running, in the spirit of the paper's continuous
"verify the pathway, not just the output" loop:

- ``MetricsRegistry`` — counters, gauges, and fixed-bucket histograms.
  Histogram quantiles are nearest-bucket-bound estimates over declared
  bucket edges, so two runs that observe the same tick-clock values
  render byte-identical output (no wall clock anywhere in the math).
- ``ServeMetrics`` — the binding from ``Tracer`` events to metrics: a
  subscription hook (``tracer.subscribe``) maps the request-lifecycle
  and scheduler events onto TTFT / inter-token-gap / page-occupancy
  histograms and pathway counters as they are emitted, before the
  bounded ring can drop them.
- ``EventLog`` — structured queryable export of the event stream:
  bounded JSONL with filter-by kind / rid / tick-window reads (the
  read-side contract a log service exposes to operators).
- ``MetricsServer`` — a stdlib ``http.server`` endpoint: ``/metrics``
  (Prometheus text exposition), ``/metrics.json`` (snapshot),
  ``/healthz``, ``/events`` (filtered JSONL), ``/timeline``
  (Chrome-trace JSON of the reconstructed per-request timelines —
  ``audit.timeline``), and ``/requests/<rid>`` (one request's full
  event history + phase decomposition).  Routing is a pure
  ``handle(path)`` function so tests exercise the full endpoint
  contract without binding a port; ``serve()`` binds it for real
  (``launch.serve --metrics-port``).
"""
from __future__ import annotations

import bisect
import json
import math
import threading
from collections import deque
from typing import Any, Callable, Iterable
from urllib.parse import parse_qs, urlsplit

from repro.audit.timeline import build_timelines, chrome_trace_bytes
from repro.audit.trace import TraceEvent, Tracer

# --------------------------------------------------------------- buckets
#: Fixed histogram bucket upper bounds (tick clock / ratios).  Declared
#: once so every consumer — engines, benchmarks, dashboards — bins
#: identically and snapshots stay comparable across runs and sites.
TTFT_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)
GAP_BUCKETS = (0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0)
OCCUPANCY_BUCKETS = (0.1, 0.25, 0.5, 0.75, 0.9, 1.0)


def _fmt(v: float) -> str:
    """Deterministic number formatting for the text exposition: integral
    values render as integers, the rest as repr (shortest round-trip)."""
    f = float(v)
    if math.isfinite(f) and f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _label_suffix(labels: dict[str, str] | None,
                  extra: dict[str, str] | None = None) -> str:
    """Deterministic ``{k="v",...}`` rendering: label keys sorted, values
    escaped per the Prometheus text format; ``extra`` (the histogram
    ``le`` bound) renders last.  Empty labels render as the empty string,
    so unlabelled series keep their exact pre-label byte format."""
    items = sorted((labels or {}).items()) + list((extra or {}).items())
    if not items:
        return ""
    def esc(v):
        return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
            "\n", r"\n")
    return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in items) + "}"


class Counter:
    """Monotonic counter."""

    def __init__(self, name: str, help: str = "",
                 labels: dict[str, str] | None = None):
        self.name, self.help = name, help
        self.labels = dict(labels or {})
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name}: negative increment {v}")
        self.value += v


class Gauge:
    """Point-in-time value (set to the latest observation)."""

    def __init__(self, name: str, help: str = "",
                 labels: dict[str, str] | None = None):
        self.name, self.help = name, help
        self.labels = dict(labels or {})
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket cumulative histogram (Prometheus semantics).

    ``buckets`` are finite upper bounds; an implicit ``+Inf`` bucket
    catches the tail.  ``quantile`` returns the upper bound of the first
    bucket whose cumulative count reaches the rank — a deterministic
    function of the observed values and the declared edges (observations
    past the last edge report the last finite edge: the estimate is
    clamped, never invented).
    """

    def __init__(self, name: str, help: str = "",
                 buckets: tuple[float, ...] = TTFT_BUCKETS,
                 labels: dict[str, str] | None = None):
        if list(buckets) != sorted(buckets) or len(set(buckets)) != len(buckets):
            raise ValueError(f"histogram {name}: buckets must be strictly "
                             f"increasing, got {buckets}")
        self.name, self.help = name, help
        self.labels = dict(labels or {})
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # +Inf last
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, float(v))] += 1
        self.sum += float(v)
        self.count += 1

    def quantile(self, q: float) -> float | None:
        """Nearest-rank quantile over bucket upper bounds; None if empty."""
        if not 0.0 < q <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {q}")
        if self.count == 0:
            return None
        rank = math.ceil(q * self.count)
        cum = 0
        for i, n in enumerate(self.counts):
            cum += n
            if cum >= rank:
                return self.buckets[min(i, len(self.buckets) - 1)]
        return self.buckets[-1]  # pragma: no cover - cum always reaches

    def snapshot(self) -> dict:
        cum, cum_counts = 0, []
        for n in self.counts:
            cum += n
            cum_counts.append(cum)
        return {
            "buckets": {_fmt(b): cum_counts[i]
                        for i, b in enumerate(self.buckets)},
            "inf": cum_counts[-1],
            "sum": round(self.sum, 6),
            "count": self.count,
            "p50": self.quantile(0.5),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Named metrics with Prometheus text + JSON snapshot rendering.

    A metric is a *series*: a name plus an optional label set (e.g. one
    ``serve_tokens_out_total`` series per cluster replica, labelled
    ``{replica="0"}``).  Registration is idempotent by (name, labels) —
    asking again returns the same instance; a name registered as one
    type cannot be re-registered as another, with or without labels.
    Rendering groups series of a name under one HELP/TYPE header and
    iterates in sorted (name, labels) order so output bytes are a pure
    function of the metric values.
    """

    def __init__(self):
        self._metrics: dict[tuple[str, str], Counter | Gauge | Histogram] = {}

    def _add(self, kind, name: str, help: str,
             labels: dict[str, str] | None = None, **kw):
        key = (name, _label_suffix(labels))
        for (n, _), existing in self._metrics.items():
            if n == name and not isinstance(existing, kind):
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{type(existing).__name__}")
        cur = self._metrics.get(key)
        if cur is not None:
            return cur
        m = kind(name, help, labels=labels, **kw)
        self._metrics[key] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: dict[str, str] | None = None) -> Counter:
        return self._add(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: dict[str, str] | None = None) -> Gauge:
        return self._add(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = TTFT_BUCKETS,
                  labels: dict[str, str] | None = None) -> Histogram:
        return self._add(Histogram, name, help, labels, buckets=buckets)

    def get(self, name: str, labels: dict[str, str] | None = None):
        return self._metrics[(name, _label_suffix(labels))]

    def series(self, name: str) -> list:
        """All series registered under ``name``, label-sorted."""
        return [m for (n, _), m in sorted(self._metrics.items())
                if n == name]

    # ---------------------------------------------------------- renderers
    def render_prometheus(self) -> str:
        """Prometheus text exposition format, deterministically ordered."""
        lines: list[str] = []
        prev_name = None
        for (name, suffix) in sorted(self._metrics):
            m = self._metrics[(name, suffix)]
            if name != prev_name:
                prev_name = name
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                kind = ("counter" if isinstance(m, Counter)
                        else "gauge" if isinstance(m, Gauge)
                        else "histogram")
                lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, (Counter, Gauge)):
                lines.append(f"{name}{suffix} {_fmt(m.value)}")
            else:
                cum = 0
                for i, b in enumerate(m.buckets):
                    cum += m.counts[i]
                    lines.append(f"{name}_bucket"
                                 f"{_label_suffix(m.labels, {'le': _fmt(b)})}"
                                 f" {cum}")
                lines.append(f"{name}_bucket"
                             f"{_label_suffix(m.labels, {'le': '+Inf'})}"
                             f" {m.count}")
                lines.append(f"{name}_sum{suffix} {_fmt(round(m.sum, 6))}")
                lines.append(f"{name}_count{suffix} {m.count}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-able snapshot: same information as the text exposition
        plus the deterministic quantile estimates.  Labelled series key
        as ``name{k="v"}``; unlabelled series keep the bare name."""
        out: dict[str, dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for (name, suffix) in sorted(self._metrics):
            m = self._metrics[(name, suffix)]
            if isinstance(m, Counter):
                out["counters"][name + suffix] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name + suffix] = m.value
            else:
                out["histograms"][name + suffix] = m.snapshot()
        return out


# ============================================================== event log


class EventLog:
    """Bounded structured log of trace events with a queryable read side.

    Subscribed to a ``Tracer`` it records every event at emission
    (surviving ring overflow).  ``query`` is the read contract: filter
    by ``kind``, ``rid`` (request id in the payload), and a tick window
    (``tick`` payload key, falling back to the tracer clock stamp), with
    an optional result ``limit`` (most recent wins).  ``dumps``/``dump``
    export JSONL, one event per line, in emission order.
    """

    def __init__(self, capacity: int = 65536):
        self._events: deque[dict] = deque(maxlen=capacity)

    def append(self, ev: TraceEvent) -> None:
        self._events.append(ev.to_dict())

    def __len__(self) -> int:
        return len(self._events)

    def records(self) -> list[dict]:
        """The retained payload dicts in emission order (the timeline
        layer's event-source contract)."""
        return list(self._events)

    @staticmethod
    def _tick(rec: dict) -> float:
        return rec.get("tick", rec.get("t", 0.0))

    def query(self, *, kind: str | None = None, rid: int | None = None,
              tick_min: float | None = None, tick_max: float | None = None,
              limit: int | None = None) -> list[dict]:
        out = [rec for rec in self._events
               if (kind is None or rec.get("kind") == kind)
               and (rid is None or rec.get("rid") == rid)
               and (tick_min is None or self._tick(rec) >= tick_min)
               and (tick_max is None or self._tick(rec) <= tick_max)]
        if limit is not None and limit >= 0:
            out = out[-limit:]
        return out

    def dumps(self, **filters: Any) -> str:
        recs = self.query(**filters) if filters else list(self._events)
        return "".join(json.dumps(r, sort_keys=True) + "\n" for r in recs)

    def dump(self, path) -> int:
        from pathlib import Path
        recs = list(self._events)
        Path(path).write_text(
            "".join(json.dumps(r, sort_keys=True) + "\n" for r in recs))
        return len(recs)


def query_jsonl(lines: Iterable[str], **filters: Any) -> list[dict]:
    """The same read-side contract over an exported JSONL stream (file
    lines), so dumped logs answer the queries the live log does."""
    log = EventLog(capacity=2 ** 31 - 1)
    for line in lines:
        line = line.strip()
        if line:
            rec = json.loads(line)
            log._events.append(rec)
    return log.query(**filters)


# ========================================================== serve binding


class ServeMetrics:
    """Tracer-event → metrics binding for the serving engines.

    ``attach(tracer)`` subscribes ``on_event``; every lifecycle event the
    engines emit updates counters/gauges/histograms live.  All values
    observed are tick-clock payloads (``ttft_ticks``, ``tick``,
    ``pages_in_use``), so the whole registry — quantiles included — is a
    deterministic function of the trace.

    ``labels`` scopes every series this binding creates (e.g.
    ``{"replica": "0"}``): a cluster attaches one ``ServeMetrics`` per
    replica tracer to a *shared* registry, and the single ``/metrics``
    endpoint exposes replica-labelled series side by side.
    """

    def __init__(self, registry: MetricsRegistry | None = None,
                 labels: dict[str, str] | None = None):
        self.registry = registry or MetricsRegistry()
        self.labels = dict(labels or {})
        r, lb = self.registry, self.labels
        self.submitted = r.counter(
            "serve_requests_submitted_total", "requests entering submit()",
            labels=lb)
        self.finished = r.counter(
            "serve_requests_finished_total", "requests run to completion",
            labels=lb)
        self.cancelled = r.counter(
            "serve_requests_cancelled_total", "requests cancelled mid-flight",
            labels=lb)
        self.preemptions = r.counter(
            "serve_preemptions_total", "slots preempted on OOM", labels=lb)
        self.recompiles = r.counter(
            "serve_recompiles_total", "jitted-step compile cache misses",
            labels=lb)
        self.tokens_out = r.counter(
            "serve_tokens_out_total", "output tokens produced", labels=lb)
        self.prefill_tokens = r.counter(
            "serve_prefill_tokens_total", "prompt tokens computed", labels=lb)
        self.cached_tokens = r.counter(
            "serve_cached_tokens_total", "prompt tokens served by prefix cache",
            labels=lb)
        self.steps = r.counter(
            "serve_steps_total", "engine ticks with at least one active lane",
            labels=lb)
        self.routed = r.counter(
            "serve_routed_total", "requests placed by the cluster router",
            labels=lb)
        self.routed_affine = r.counter(
            "serve_routed_affine_total",
            "router placements on a deepest-prefix-match replica", labels=lb)
        self.routed_spill = r.counter(
            "serve_routed_spill_total",
            "router placements spilled off a saturated affine replica",
            labels=lb)
        self.swap_out_pages = r.counter(
            "serve_swap_out_pages_total",
            "KV pages parked on the host swap tier (preempt + prefix "
            "spill)", labels=lb)
        self.swap_in_pages = r.counter(
            "serve_swap_in_pages_total",
            "KV pages restored from the host swap tier (readmit + prefix "
            "page-in)", labels=lb)
        self.host_pages = r.gauge(
            "serve_host_pages_in_use", "host swap tier pages resident",
            labels=lb)
        self.active_lanes = r.gauge(
            "serve_active_lanes", "lanes active in the latest step", labels=lb)
        self.pages_total = r.gauge(
            "serve_pages_total", "page-pool capacity (engine-init)", labels=lb)
        self.prefix_hit_rate = r.gauge(
            "serve_prefix_hit_rate", "cached / (cached + prefill) tokens",
            labels=lb)
        self.ttft = r.histogram(
            "serve_ttft_ticks", "submit-to-first-token latency (tick clock)",
            buckets=TTFT_BUCKETS, labels=lb)
        self.gap = r.histogram(
            "serve_decode_gap_ticks",
            "mean inter-token gap per finished request (tick clock)",
            buckets=GAP_BUCKETS, labels=lb)
        self.occupancy = r.histogram(
            "serve_page_occupancy", "pages in use / pool capacity, sampled "
            "at admission and release", buckets=OCCUPANCY_BUCKETS, labels=lb)
        self._first_tick: dict[int, float] = {}   # rid -> first-token tick
        self._pages = 0

    # ------------------------------------------------------------- attach
    def attach(self, tracer: Tracer) -> Callable[[TraceEvent], None]:
        return tracer.subscribe(self.on_event)

    def _observe_pages(self, data: dict) -> None:
        if self._pages and "pages_in_use" in data:
            self.occupancy.observe(data["pages_in_use"] / self._pages)

    def on_event(self, ev: TraceEvent) -> None:
        d = ev.data
        if ev.kind == "submit":
            self.submitted.inc()
        elif ev.kind == "engine-init":
            self._pages = d.get("pages", 0)
            self.pages_total.set(self._pages)
        elif ev.kind == "admit":
            cached = d.get("cached_tokens", 0)
            if cached:
                self.cached_tokens.inc(cached)
                self._update_hit_rate()
            self._observe_pages(d)
        elif ev.kind == "first-token":
            if "ttft_ticks" in d:
                self.ttft.observe(d["ttft_ticks"])
            if "rid" in d and "tick" in d:
                self._first_tick.setdefault(d["rid"], d["tick"])
        elif ev.kind == "step":
            self.steps.inc()
            self.active_lanes.set(d.get("lanes", 0))
            if d.get("prefill_tokens"):
                self.prefill_tokens.inc(d["prefill_tokens"])
                self._update_hit_rate()
        elif ev.kind == "finish":
            self.finished.inc()
            n = d.get("tokens_out", 0)
            self.tokens_out.inc(n)
            first = self._first_tick.pop(d.get("rid"), None)
            if first is not None and "tick" in d:
                self.gap.observe((d["tick"] - first) / max(n - 1, 1))
            self._observe_pages(d)
        elif ev.kind == "cancel":
            self.cancelled.inc()
            self._first_tick.pop(d.get("rid"), None)
            self._observe_pages(d)
        elif ev.kind == "preempt":
            self.preemptions.inc()
            self._observe_pages(d)
        elif ev.kind == "swap-out":
            self.swap_out_pages.inc(d.get("pages", 0))
            self.host_pages.set(d.get("host_pages_in_use", 0))
            self._observe_pages(d)
        elif ev.kind == "swap-in":
            self.swap_in_pages.inc(d.get("pages", 0))
            self.host_pages.set(d.get("host_pages_in_use", 0))
            self._observe_pages(d)
        elif ev.kind == "compile":
            self.recompiles.inc()
        elif ev.kind == "route":
            self.routed.inc()
            if d.get("decision") in ("affine", "spill"):
                # spill is still a router *decision* series; affinity
                # conversion is the affine counter alone
                (self.routed_affine if d["decision"] == "affine"
                 else self.routed_spill).inc()

    def _update_hit_rate(self) -> None:
        total = self.cached_tokens.value + self.prefill_tokens.value
        if total:
            self.prefix_hit_rate.set(self.cached_tokens.value / total)

    def observe_report(self, report: dict) -> None:
        """Fold an engine report's exact lifetime counters in (the
        subscription sees events; the report carries counters the trace
        does not itemise, e.g. prefill token totals)."""
        if "prefill_tokens" in report:
            delta = report["prefill_tokens"] - self.prefill_tokens.value
            if delta > 0:
                self.prefill_tokens.inc(delta)
            self._update_hit_rate()


# ============================================================ http server


class MetricsServer:
    """Stdlib HTTP exposition of a registry + event log.

    ``handle(path)`` is the entire routing contract as a pure function —
    ``(status, content_type, body)`` — so tests drive every endpoint
    without a socket.  ``serve(port)`` binds a ``ThreadingHTTPServer``
    around it in a daemon thread for real deployments.
    """

    def __init__(self, registry: MetricsRegistry,
                 log: EventLog | None = None):
        self.registry = registry
        self.log = log
        self._httpd = None
        self._thread = None

    # ------------------------------------------------------------ routing
    def handle(self, path: str) -> tuple[int, str, bytes]:
        url = urlsplit(path)
        q = parse_qs(url.query)
        route = url.path.rstrip("/") or "/"
        if route == "/healthz":
            return 200, "application/json", b'{"ok": true}\n'
        if route == "/metrics":
            if q.get("format", [""])[0] == "json":
                return self._json_snapshot()
            body = self.registry.render_prometheus().encode()
            return 200, "text/plain; version=0.0.4", body
        if route == "/metrics.json":
            return self._json_snapshot()
        if route == "/events":
            if self.log is None:
                return 404, "text/plain", b"no event log attached\n"
            try:
                filters: dict[str, Any] = {}
                if "kind" in q:
                    filters["kind"] = q["kind"][0]
                if "rid" in q:
                    filters["rid"] = int(q["rid"][0])
                if "tick_min" in q:
                    filters["tick_min"] = float(q["tick_min"][0])
                if "tick_max" in q:
                    filters["tick_max"] = float(q["tick_max"][0])
                if "limit" in q:
                    filters["limit"] = int(q["limit"][0])
            except ValueError as e:
                return 400, "text/plain", f"bad query: {e}\n".encode()
            body = self.log.dumps(**filters).encode()
            return 200, "application/x-ndjson", body
        if route == "/timeline":
            if self.log is None:
                return 404, "text/plain", b"no event log attached\n"
            body = chrome_trace_bytes(build_timelines(self.log))
            return 200, "application/json", body
        if route.startswith("/requests/"):
            if self.log is None:
                return 404, "text/plain", b"no event log attached\n"
            try:
                rid = int(route.rsplit("/", 1)[1])
            except ValueError:
                return 400, "text/plain", b"bad request id\n"
            recs = self.log.query(rid=rid)
            if not recs:
                return (404, "text/plain",
                        f"no events for rid {rid}\n".encode())
            tl = build_timelines(recs).get(rid)
            # strip the wall-clock stamp so the body, like /metrics, is a
            # deterministic function of the tick-clock trace
            doc = {"rid": rid,
                   "events": [{k: v for k, v in r.items() if k != "t"}
                              for r in recs],
                   "timeline": None if tl is None else tl.describe()}
            body = (json.dumps(doc, sort_keys=True, indent=1) + "\n").encode()
            return 200, "application/json", body
        return 404, "text/plain", f"unknown path {route!r}\n".encode()

    def _json_snapshot(self) -> tuple[int, str, bytes]:
        body = (json.dumps(self.registry.snapshot(), sort_keys=True,
                           indent=1) + "\n").encode()
        return 200, "application/json", body

    # ------------------------------------------------------------ binding
    def serve(self, port: int = 0, host: str = "127.0.0.1") -> int:
        """Bind and serve in a daemon thread; returns the bound port
        (``port=0`` picks an ephemeral one)."""
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 - stdlib contract
                status, ctype, body = outer.handle(self.path)
                self.send_response(status)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # noqa: ARG002 - silence stderr
                pass

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self._httpd.server_address[1]

    @property
    def port(self) -> int | None:
        return self._httpd.server_address[1] if self._httpd else None

    def close(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
            self._thread = None
