"""Config module for --arch phi3-medium-14b (assignment table)."""
from repro.configs.archs import PHI3_MEDIUM_14B as CONFIG

CONFIG = CONFIG
