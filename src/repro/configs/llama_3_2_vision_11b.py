"""Config module for --arch llama-3.2-vision-11b (assignment table)."""
from repro.configs.archs import LLAMA32_VISION_11B as CONFIG

CONFIG = CONFIG
