"""The ten assigned architectures, exactly as specified in the assignment
table (``[source; tier]`` recorded in ``source``).  Each also exists as its
own module (``configs/<id>.py``) so ``--arch <id>`` resolves either way."""
from __future__ import annotations

from repro.configs.base import ModelConfig

LLAMA32_VISION_11B = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, cross_every=5, n_image_tokens=1600,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)

MAMBA2_2P7B = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    ssm_groups=1, conv_width=4, ssd_chunk=256,
    source="arXiv:2405.21060; unverified",
)

PHI3_MINI_3P8B = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064, rope_theta=10_000.0,
    source="arXiv:2404.14219; unverified",
)

PHI3_MEDIUM_14B = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab_size=100352, rope_theta=10_000.0,
    source="arXiv:2404.14219; unverified",
)

DEEPSEEK_7B = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400, rope_theta=10_000.0,
    source="arXiv:2401.02954; hf",
)

DEEPSEEK_CODER_33B = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19200, vocab_size=32256, rope_theta=100_000.0,
    source="arXiv:2401.14196; hf",
)

QWEN3_MOE_30B_A3B = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, n_experts=128, top_k=8, qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

GRANITE_MOE_1B_A400M = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155, n_experts=32, top_k=8,
    rope_theta=10_000.0, tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

WHISPER_MEDIUM = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865, n_encoder_layers=24,
    n_audio_frames=1500, decoder_train_len=448, rope_theta=0.0,
    source="arXiv:2212.04356; unverified",
)

ZAMBA2_2P7B = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000, ssm_state=64, ssm_head_dim=64,
    ssm_expand=2, ssm_groups=1, conv_width=4, ssd_chunk=256, attn_every=6,
    source="arXiv:2411.15242; hf",
)

ALL_ARCHS: dict[str, ModelConfig] = {
    m.name: m
    for m in (
        LLAMA32_VISION_11B, MAMBA2_2P7B, PHI3_MINI_3P8B, PHI3_MEDIUM_14B,
        DEEPSEEK_7B, DEEPSEEK_CODER_33B, QWEN3_MOE_30B_A3B,
        GRANITE_MOE_1B_A400M, WHISPER_MEDIUM, ZAMBA2_2P7B,
    )
}

# Shape applicability (DESIGN.md §6): long_500k only for sub-quadratic
# sequence mixing; every arch here has a decoder so decode shapes run for all.
SUBQUADRATIC = {"mamba2-2.7b", "zamba2-2.7b"}


def applicable_shapes(name: str) -> list[str]:
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if name in SUBQUADRATIC:
        shapes.append("long_500k")
    return shapes
