"""Config module for --arch qwen3-moe-30b-a3b (assignment table)."""
from repro.configs.archs import QWEN3_MOE_30B_A3B as CONFIG

CONFIG = CONFIG
