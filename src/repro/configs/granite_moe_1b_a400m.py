"""Config module for --arch granite-moe-1b-a400m (assignment table)."""
from repro.configs.archs import GRANITE_MOE_1B_A400M as CONFIG

CONFIG = CONFIG
