"""Config module for --arch zamba2-2.7b (assignment table)."""
from repro.configs.archs import ZAMBA2_2P7B as CONFIG

CONFIG = CONFIG
