from repro.configs.base import (
    SHAPES,
    SINGLE_POD,
    MULTI_POD,
    TINY_MESH,
    MeshConfig,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    TrainConfig,
    reduced,
)
from repro.configs.archs import ALL_ARCHS, SUBQUADRATIC, applicable_shapes

__all__ = [
    "SHAPES", "SINGLE_POD", "MULTI_POD", "TINY_MESH",
    "MeshConfig", "ModelConfig", "RunConfig", "ShapeConfig", "TrainConfig",
    "reduced", "ALL_ARCHS", "SUBQUADRATIC", "applicable_shapes",
]
