"""Config module for --arch deepseek-7b (assignment table)."""
from repro.configs.archs import DEEPSEEK_7B as CONFIG

CONFIG = CONFIG
