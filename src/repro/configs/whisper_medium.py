"""Config module for --arch whisper-medium (assignment table)."""
from repro.configs.archs import WHISPER_MEDIUM as CONFIG

CONFIG = CONFIG
