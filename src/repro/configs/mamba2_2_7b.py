"""Config module for --arch mamba2-2.7b (assignment table)."""
from repro.configs.archs import MAMBA2_2P7B as CONFIG

CONFIG = CONFIG
