"""Configuration dataclasses for the repro framework.

A run is fully described by (ModelConfig, ShapeConfig, MeshConfig,
TrainConfig) — together these form the portable part of the environment
manifest (core/manifest.py).  The host binding (device kind, real mesh) is
attached late, mirroring the paper's container-image / host-driver split.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  One instance per assigned arch.

    ``family`` selects the block layout:
      dense   — decoder-only, attention+MLP blocks
      moe     — decoder-only, attention+MoE blocks
      ssm     — decoder-only, Mamba2 (SSD) blocks, attention-free
      hybrid  — Mamba2 blocks + a globally *shared* attention block every
                ``attn_every`` blocks (zamba2)
      encdec  — encoder-decoder (whisper); frontend stubbed
      vlm     — decoder-only with cross-attention blocks every
                ``cross_every`` layers attending to stubbed patch embeddings
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256
    # --- hybrid (zamba2) ---
    attn_every: int = 6        # a shared attention block after every N-1 mamba blocks
    # --- vlm ---
    cross_every: int = 5       # one cross-attn block per `cross_every` self layers
    n_image_tokens: int = 1600
    # --- encdec (whisper) ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500  # encoder sequence length for non-train shapes
    decoder_train_len: int = 448
    # --- common ---
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    qk_norm: bool = False      # qwen3-style per-head q/k RMSNorm
    dtype: str = "bfloat16"
    # ref: citation string from the assignment table
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding tables padded to a multiple of 256 so the vocab dim is
        always shardable over a 16-wide model axis (Megatron-style)."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D model-FLOPs)."""
        from repro.models import stack  # local import to avoid cycles

        return stack.param_count(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        from repro.models import stack

        return stack.param_count(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str       # train_4k | prefill_32k | decode_32k | long_500k
    kind: str       # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class MeshConfig:
    """Logical device mesh.  ``data`` carries batch + FSDP, ``model`` carries
    tensor/expert parallelism, ``pod`` (optional) is the cross-pod DP axis."""

    shape: tuple[int, ...] = (16, 16)
    axes: tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def model_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axes if a == "model")

    def axis_size(self, name: str) -> int:
        if name not in self.axes:
            return 1
        return self.shape[self.axes.index(name)]


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))
# Reduced meshes for CPU-measured benchmarks / tests.
TINY_MESH = MeshConfig((1, 1), ("data", "model"))


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    seed: int = 0
    # remat: 'none' | 'full' | 'selective' (save only block boundaries)
    remat: str = "full"
    # microbatching (gradient accumulation) — 0 disables
    microbatches: int = 0
    # gradient compression: 'none' | 'int8_ef'
    grad_compress: str = "none"


@dataclass(frozen=True)
class RunConfig:
    """Everything needed to lower one cell."""

    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = SINGLE_POD
    train: TrainConfig = field(default_factory=TrainConfig)
    # sharding rule-set name (parallel/rules.py): 'baseline' is the
    # paper-faithful portable default, others are perf-pass variants.
    rules: str = "baseline"
    use_pallas: bool = False

    def cell_id(self) -> str:
        pods = "mp" if "pod" in self.mesh.axes else "sp"
        return f"{self.model.name}/{self.shape.name}/{pods}/{self.rules}"


def reduced(model: ModelConfig, **overrides: Any) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small: dict[str, Any] = dict(
        n_layers=min(model.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(model.n_kv_heads, 2),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_experts=min(model.n_experts, 8) if model.n_experts else 0,
        top_k=min(model.top_k, 2) if model.top_k else 0,
        ssm_state=min(model.ssm_state, 16) if model.ssm_state else 0,
        ssm_head_dim=32,
        ssd_chunk=16,
        n_image_tokens=16,
        n_encoder_layers=2 if model.n_encoder_layers else 0,
        n_audio_frames=32,
        decoder_train_len=16,
        attn_every=2,
        cross_every=2,
        name=model.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(model, **small)
