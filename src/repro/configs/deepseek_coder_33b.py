"""Config module for --arch deepseek-coder-33b (assignment table)."""
from repro.configs.archs import DEEPSEEK_CODER_33B as CONFIG

CONFIG = CONFIG
