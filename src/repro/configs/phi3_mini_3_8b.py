"""Config module for --arch phi3-mini-3.8b (assignment table)."""
from repro.configs.archs import PHI3_MINI_3P8B as CONFIG

CONFIG = CONFIG
