"""HLO transport-pathway inspector.

The TPU analogue of the paper's debug-log analysis (§3 "Automating Domain
Expertise"): instead of grepping UCX/NCCL traces for TCP fallbacks or
missing GPUDirect, we parse the compiled HLO — the authoritative record of
which collective "transports" XLA actually chose — and derive:

  * every collective op (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute), its payload bytes, group size, and
    how many times it executes (while-loop trip counts are recovered from
    the paired condition computations, so per-layer collectives inside
    scan-over-layers are counted per layer);
  * per-device communication bytes under a ring model
    (all-reduce 2(g-1)/g, gather/scatter (g-1)/g, permute 1.0);
  * misconfiguration findings (core/diagnostics.py policies): redundant
    re-gathers, all-reduce where reduce-scatter would do, replicated large
    buffers, host transfers — the "suboptimal transport pathway" class of
    bugs the paper detects by expert review, automated here.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

# bytes moved per device / payload bytes, ring algorithms
_RING_FACTOR = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "ragged-all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(" + "|".join(COLLECTIVES) + r")(?:-start|-done)?\(", re.M)
# the while operand may carry a nested tuple type, e.g.
# ``while((s32[], f32[64,64]{1,0}) %tuple)`` — match lazily up to the
# closing paren that precedes ``condition=``
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*?\)\s*->", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _parse_shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuple types)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    name: str
    kind: str
    payload_bytes: int      # result buffer bytes (per device, SPMD module)
    group_size: int
    computation: str
    trips: int = 1
    f32_activation: bool = False  # f32 payload, activation-shaped (rank>=3)

    @property
    def moved_bytes(self) -> float:
        """Per-device bytes on the wire across all executions."""
        return (_RING_FACTOR[self.kind](max(self.group_size, 2))
                * self.payload_bytes * self.trips)

    @property
    def tpu_adjusted_bytes(self) -> float:
        """XLA:CPU promotes bf16 dot operands to f32 and hoists the convert
        through collectives, doubling activation payloads on the wire.  TPU
        has native bf16 MXU dots, so the f32 width is a host artifact —
        the same image would move half these bytes there (the manifest/
        attestation layer records both).  Count such ops at bf16 width."""
        b = self.moved_bytes
        return b / 2 if self.f32_activation else b


@dataclass
class TransportReport:
    ops: list[CollectiveOp] = field(default_factory=list)
    findings: list[dict] = field(default_factory=list)

    @property
    def total_moved_bytes(self) -> float:
        return sum(op.moved_bytes for op in self.ops)

    @property
    def tpu_adjusted_moved_bytes(self) -> float:
        return sum(op.tpu_adjusted_bytes for op in self.ops)

    def by_kind(self) -> dict[str, float]:
        out: dict[str, float] = defaultdict(float)
        for op in self.ops:
            out[op.kind] += op.moved_bytes
        return dict(out)

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = defaultdict(int)
        for op in self.ops:
            out[op.kind] += op.trips
        return dict(out)

    def summary(self) -> dict:
        return {
            "total_moved_bytes": self.total_moved_bytes,
            "tpu_adjusted_moved_bytes": self.tpu_adjusted_moved_bytes,
            "by_kind": self.by_kind(),
            "counts": self.counts(),
            "n_findings": len(self.findings),
            "findings": self.findings,
        }


def _split_computations(hlo: str) -> dict[str, str]:
    """Map computation name -> its text block."""
    comps: dict[str, str] = {}
    current = None
    lines: list[str] = []
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m and ("{" in line):
            if current is not None:
                comps[current] = "\n".join(lines)
            current = m.group(1)
            lines = [line]
        else:
            lines.append(line)
    if current is not None:
        comps[current] = "\n".join(lines)
    return comps


_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?\s*:\s*"?(\d+)')


def _trip_count(cond_text: str) -> int | None:
    """Recover a static while trip count from its condition computation:
    the compare-against constant pattern XLA emits for counted loops."""
    consts = re.findall(r"=\s*s(?:32|64)\[\]\s+constant\((\d+)\)", cond_text)
    if not consts:
        return None
    # The loop bound is the largest integer constant compared against.
    if re.search(r"compare\(", cond_text):
        return max(int(c) for c in consts)
    return None


def _group_size(attr_line: str, n_partitions: int) -> int:
    m = _GROUPS_V2_RE.search(attr_line)
    if m:
        n_groups, g = int(m.group(1)), int(m.group(2))
        return g
    m = _GROUPS_RE.search(attr_line)
    if m:
        return len(m.group(1).split(","))
    if _PAIRS_RE.search(attr_line):
        return 2
    return n_partitions


def parse_hlo(hlo: str, n_partitions: int = 1) -> TransportReport:
    report = TransportReport()
    comps = _split_computations(hlo)

    # while-loop trip counts: map body computation -> trips.  Primary
    # source: the while instruction's backend_config known_trip_count;
    # fallback: the condition computation's compare constant.
    body_trips: dict[str, int] = {}
    for m in _WHILE_RE.finditer(hlo):
        cond, body = m.group(1), m.group(2)
        line_end = hlo.find("\n", m.end())
        line = hlo[m.start():line_end if line_end > 0 else len(hlo)]
        tm = _TRIP_RE.search(line)
        trips = int(tm.group(1)) if tm else _trip_count(comps.get(cond, ""))
        if trips:
            # nested whiles multiply: walk up later if needed (one level
            # of nesting is what scan-in-scan produces)
            body_trips[body] = body_trips.get(body, 1) * trips

    # propagate nesting: if a body contains a while whose body has trips,
    # multiply (two-level scan: hybrid/vlm groups)
    for name, text in comps.items():
        outer = body_trips.get(name)
        if not outer:
            continue
        for m in _WHILE_RE.finditer(text):
            inner_body = m.group(2)
            if inner_body in body_trips:
                body_trips[inner_body] *= outer

    for comp_name, text in comps.items():
        trips = body_trips.get(comp_name, 1)
        for m in _INSTR_RE.finditer(text):
            name, type_str, kind = m.group(1), m.group(2), m.group(3)
            if name.endswith(".done") or "-done" in name:
                continue  # count the -start only (async pairs)
            line = text[m.start():text.find("\n", m.start())]
            payload = _parse_shape_bytes(type_str)
            if kind == "all-to-all" and type_str.startswith("("):
                # tuple all-to-all: payload is the sum, already handled
                pass
            g = _group_size(line, n_partitions)
            f32_act = bool(re.match(r"\(?f32\[\d+,\d+,\d+", type_str))
            report.ops.append(CollectiveOp(
                name=name, kind=kind, payload_bytes=payload,
                group_size=g, computation=comp_name, trips=trips,
                f32_activation=f32_act))

    _attach_findings(report, hlo)
    return report


def _attach_findings(report: TransportReport, hlo: str) -> None:
    """Pathway-misconfiguration heuristics (paper §3/§8 automated)."""
    # 1. redundant gathers: same payload+kind+group repeated in one comp
    seen: dict[tuple, list[CollectiveOp]] = defaultdict(list)
    for op in report.ops:
        if op.kind == "all-gather":
            seen[(op.computation, op.payload_bytes, op.group_size)].append(op)
    for key, ops in seen.items():
        if len(ops) > 2:  # q,k,v gathers of same-shaped weights are fine; >2 identical is suspect
            report.findings.append({
                "severity": "info",
                "kind": "repeated-all-gather",
                "detail": f"{len(ops)} identical all-gathers of "
                          f"{ops[0].payload_bytes} B in {key[0]} — check for "
                          f"a missed CSE or a re-gather across uses",
            })
    # 2. large all-reduce where a reduce-scatter(+later gather) pattern is
    #    cheaper (gradient reduction): flag all-reduces > 256 MiB payload.
    for op in report.ops:
        if op.kind == "all-reduce" and op.payload_bytes > 256 * 2**20:
            report.findings.append({
                "severity": "warn",
                "kind": "monolithic-all-reduce",
                "detail": f"{op.name}: {op.payload_bytes/2**20:.0f} MiB "
                          f"all-reduce (g={op.group_size}); reduce-scatter + "
                          f"sharded update halves wire bytes",
            })
    # 3. dtype-promotion-inflated collectives (host-environment artifact:
    #    XLA:CPU promotes bf16 dot operands to f32 and hoists the convert
    #    through the collective; native-bf16 hosts move half the bytes).
    infl = sum(op.moved_bytes - op.tpu_adjusted_bytes for op in report.ops)
    if infl > 2**30:
        report.findings.append({
            "severity": "info",
            "kind": "promotion-inflated-collectives",
            "detail": f"{infl/2**30:.1f} GiB of f32 activation collectives "
                      f"are bf16 on a native-bf16 host (tpu_adjusted_moved_"
                      f"bytes reports the corrected term)",
        })
    # 4. host transfers in the hot path
    if re.search(r"\b(outfeed|infeed|send|recv)\(", hlo):
        report.findings.append({
            "severity": "warn",
            "kind": "host-transfer",
            "detail": "infeed/outfeed/send/recv found in compiled module",
        })


# ===================================================================
# Execution-weighted HLO cost model
#
# XLA's compiled.cost_analysis() counts each while body ONCE, so with
# scan-over-layers it under-reports flops/bytes by ~n_layers.  This model
# re-derives both with loop-trip multiplication:
#   * trips come from the while instruction's backend_config
#     known_trip_count (fallback: the condition's compare constant);
#   * dot flops = 2 · |result| · K (K = lhs contracting dims);
#   * elementwise/reduce ops count |result| arithmetic flops;
#   * HBM bytes = operand + result bytes of top-level and while-body
#     instructions; fusion bodies contribute flops but their internal
#     dataflow is VMEM-resident, so only the fusion's boundary operands
#     count toward bytes (this is the TPU memory model, where a fused
#     region streams HBM→VMEM once).
# ===================================================================

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare", "select",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "sqrt", "rsqrt", "cbrt", "sine", "cosine", "tan", "atan2", "logistic",
    "convert", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "clamp", "is-finite", "erf", "remainder", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "stochastic-convert",
}
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "opt-barrier", "while", "conditional", "call", "custom-call",
    "rng-bit-generator", "rng", "partition-id", "replica-id", "domain",
}

# Ops whose operands/results plausibly round-trip HBM on TPU.  Standalone
# elementwise ops are EXCLUDED from the bytes model: the TPU compiler fuses
# them into neighbouring dots/copies, so counting them (as the unfused CPU
# HLO would suggest) over-states HBM traffic by orders of magnitude.  Their
# flops still count.  This makes the bytes term a fusion-optimistic model —
# stated as such wherever reported.
_BYTES_OPS = {
    "dot", "convolution", "copy", "slice", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "concatenate", "pad",
    "transpose", "reverse", "sort", "reduce", "reduce-window", "iota",
    "copy-start", "copy-done",
}

# type string: either a tuple "(...)" (may contain /*index=N*/ comments,
# hence [^()] rather than [^=]) or a plain array type.
_INSTR_FULL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\(", re.M)
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dims_of(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """All (dtype, dims) arrays in an HLO type string (tuples flattened)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((dt, dims))
    # scalar arrays like f32[] :
    for m in re.finditer(r"(\w+)\[\]", type_str):
        if m.group(1) in _DTYPE_BYTES:
            out.append((m.group(1), ()))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _dims_of(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _operand_names(line: str, op_start: int) -> list[str]:
    """Names inside the op's top-level parens."""
    i = line.find("(", op_start)
    depth = 0
    j = i
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
    seg = line[i:j + 1]
    return re.findall(r"%([\w.\-]+)", seg)


class _Comp:
    __slots__ = ("name", "dot_flops", "arith_flops", "bytes", "transcendentals",
                 "while_calls", "fusion_calls", "call_calls")

    def __init__(self, name: str):
        self.name = name
        self.dot_flops = 0.0
        self.arith_flops = 0.0
        self.bytes = 0.0
        self.transcendentals = 0.0
        self.while_calls: list[tuple[str, str, int]] = []  # (cond, body, trips)
        self.fusion_calls: list[str] = []
        self.call_calls: list[str] = []


def hlo_cost(hlo: str) -> dict:
    comps_text = _split_computations(hlo)
    comps: dict[str, _Comp] = {}

    for cname, text in comps_text.items():
        comp = _Comp(cname)
        shapes: dict[str, str] = {}
        # parameters appear in the signature: name: type
        header = text.split("{", 1)[0]
        for m in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^)]*\)|[\w\[\],]+))",
                             header):
            shapes[m.group(1)] = m.group(2)
        lines = text.splitlines()
        for line in lines:
            m = _INSTR_FULL_RE.match(line)
            if not m:
                continue
            name, type_str, op = m.group(1), m.group(2), m.group(3)
            shapes[name] = type_str
            if op == "while":
                wm = _WHILE_RE.search(line)
                trips = None
                tm = _TRIP_RE.search(line)
                if tm:
                    trips = int(tm.group(1))
                if wm:
                    comp.while_calls.append(
                        (wm.group(1), wm.group(2), trips or 0))
                continue
            if op == "fusion":
                # flops of the body count; boundary bytes do NOT — the CPU
                # backend wraps every elementwise op in a kLoop fusion, so
                # fusion traffic here is what the TPU compiler would fuse
                # away.  Dots/copies/DUS below carry the honest HBM model.
                cm = _CALLS_RE.search(line)
                if cm:
                    comp.fusion_calls.append(cm.group(1))
                continue
            if op == "call":
                cm = _TO_APPLY_RE.search(line) or _CALLS_RE.search(line)
                if cm:
                    comp.call_calls.append(cm.group(1))
                continue
            if op in _FREE_OPS:
                continue

            ops_names = _operand_names(line, m.end() - 1)
            if op in _BYTES_OPS:
                res_bytes = _bytes_of(type_str)
                opd_bytes = sum(_bytes_of(shapes.get(o, "")) for o in ops_names)
                comp.bytes += res_bytes + opd_bytes

            arrays = _dims_of(type_str)
            n_res = 0
            if arrays:
                n = 1
                for d in arrays[0][1]:
                    n *= d
                n_res = n
            if op == "dot":
                k = 1
                lhs = shapes.get(ops_names[0], "") if ops_names else ""
                lhs_arrays = _dims_of(lhs)
                cm = _LHS_CONTRACT_RE.search(line)
                if cm and lhs_arrays:
                    for idx in cm.group(1).split(","):
                        if idx:
                            k *= lhs_arrays[0][1][int(idx)]
                comp.dot_flops += 2.0 * n_res * k
            elif op in _ELEMENTWISE:
                comp.arith_flops += n_res
                if op in ("exponential", "log", "tanh", "logistic", "sine",
                          "cosine", "sqrt", "rsqrt", "power", "erf"):
                    comp.transcendentals += n_res
            elif op in ("reduce", "reduce-window"):
                opd = _dims_of(shapes.get(ops_names[0], "")) if ops_names else []
                n_opd = 0
                if opd:
                    n = 1
                    for d in opd[0][1]:
                        n *= d
                    n_opd = n
                comp.arith_flops += n_opd
            elif op.startswith("all-") or op in ("reduce-scatter",
                                                 "collective-permute"):
                pass  # collectives counted by parse_hlo
        comps[cname] = comp

    # --- propagate execution multipliers from ENTRY ---
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fallback: computation not matched; pick the one with a while
        entry = next(iter(comps))

    totals = {"dot_flops": 0.0, "arith_flops": 0.0, "bytes": 0.0,
              "transcendentals": 0.0}
    visited_guard: set[tuple[str, int]] = set()

    def visit(cname: str, mult: float, bytes_on: bool, depth: int = 0):
        if depth > 50 or cname not in comps:
            return
        comp = comps[cname]
        totals["dot_flops"] += mult * comp.dot_flops
        totals["arith_flops"] += mult * comp.arith_flops
        totals["transcendentals"] += mult * comp.transcendentals
        if bytes_on:
            totals["bytes"] += mult * comp.bytes
        for cond, body, trips in comp.while_calls:
            t = max(trips, 1)
            visit(body, mult * t, bytes_on, depth + 1)
            visit(cond, mult * t, False, depth + 1)
        for callee in comp.fusion_calls:
            visit(callee, mult, False, depth + 1)  # fusion body: flops only
        for callee in comp.call_calls:
            visit(callee, mult, bytes_on, depth + 1)

    visit(entry, 1.0, True)
    totals["flops"] = totals["dot_flops"] + totals["arith_flops"]
    return totals
