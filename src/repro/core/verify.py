"""Dual-environment verification harness.

The paper's method: run the identical benchmark natively and inside the
container; agreement within noise bands *is* the portability proof, and
divergence localizes misconfiguration (in either environment — §8 found
host-side regressions this way).

Here an "environment" is any way of executing the same workload: the
pure-jnp oracle vs the Pallas kernel (interpret), the reference sharding
vs an optimized rule set, mesh A vs mesh B, or commit N vs commit N+1.
The harness runs both, compares numerics and timing with the paper's
statistics (mean ± min/max error bars, relative agreement bands), and
emits machine-checkable verdicts that CI can gate on.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


@dataclass
class EnvResult:
    name: str
    wall_times: list[float] = field(default_factory=list)
    value: Any = None

    @property
    def mean(self) -> float:
        return float(np.mean(self.wall_times)) if self.wall_times else float("nan")

    @property
    def std(self) -> float:
        return float(np.std(self.wall_times)) if self.wall_times else float("nan")

    @property
    def vmin(self) -> float:
        return float(np.min(self.wall_times)) if self.wall_times else float("nan")

    @property
    def vmax(self) -> float:
        return float(np.max(self.wall_times)) if self.wall_times else float("nan")


@dataclass
class Verdict:
    kind: str          # numeric | timing
    ok: bool
    detail: str
    measured: float
    bound: float


@dataclass
class DualEnvReport:
    a: EnvResult
    b: EnvResult
    verdicts: list[Verdict] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(v.ok for v in self.verdicts)

    def summary(self) -> dict:
        return {
            "a": {"name": self.a.name, "mean_s": self.a.mean,
                  "min_s": self.a.vmin, "max_s": self.a.vmax},
            "b": {"name": self.b.name, "mean_s": self.b.mean,
                  "min_s": self.b.vmin, "max_s": self.b.vmax},
            "overhead_pct": 100.0 * (self.b.mean - self.a.mean)
                            / max(self.a.mean, 1e-12),
            "verdicts": [vars(v) for v in self.verdicts],
            "ok": self.ok,
        }


class DualEnvHarness:
    """Run one workload under two environments and compare.

    ``workload(env_fn) -> value`` where env_fn is the environment's
    callable; numeric agreement uses ``np.allclose``-style relative bands
    (the paper's NCCL runs agreed to 0.01–1.3 %; kernels vs oracles must
    agree to fp tolerance), timing agreement uses a relative overhead band
    (the paper tolerates a constant 12–19 % only when it does not grow
    with scale — callers check that with two harness runs at two scales).
    """

    def __init__(self, *, repeats: int = 3, warmup: int = 1):
        self.repeats = repeats
        self.warmup = warmup

    def _run(self, name: str, fn: Callable[[], Any]) -> EnvResult:
        res = EnvResult(name=name)
        for _ in range(self.warmup):
            res.value = fn()
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            res.value = fn()
            res.wall_times.append(time.perf_counter() - t0)
        return res

    def compare(self, name_a: str, fn_a: Callable[[], Any],
                name_b: str, fn_b: Callable[[], Any], *,
                rtol: float = 2e-2, atol: float = 1e-5,
                timing_band: float | None = None) -> DualEnvReport:
        a = self._run(name_a, fn_a)
        b = self._run(name_b, fn_b)
        report = DualEnvReport(a=a, b=b)

        if a.value is not None and b.value is not None:
            va = np.asarray(a.value, dtype=np.float64)
            vb = np.asarray(b.value, dtype=np.float64)
            if va.shape == vb.shape:
                denom = np.maximum(np.abs(va), atol)
                rel = float(np.max(np.abs(va - vb) / denom))
                report.verdicts.append(Verdict(
                    kind="numeric", ok=bool(rel <= rtol),
                    detail=f"max rel err {rel:.3e} vs band {rtol:.1e}",
                    measured=rel, bound=rtol))
            else:
                report.verdicts.append(Verdict(
                    kind="numeric", ok=False,
                    detail=f"shape mismatch {va.shape} vs {vb.shape}",
                    measured=float("nan"), bound=rtol))

        if timing_band is not None and a.mean > 0:
            over = (b.mean - a.mean) / a.mean
            report.verdicts.append(Verdict(
                kind="timing", ok=bool(over <= timing_band),
                detail=f"overhead {100*over:.1f}% vs band {100*timing_band:.0f}%",
                measured=over, bound=timing_band))
        return report


def constant_vs_scaling_overhead(overheads: dict[int, float],
                                 tol: float = 0.5) -> str:
    """Classify an overhead curve the way the paper does for GPU-Arbor
    (§6.2.3): a constant relative overhead is a per-launch cost
    (acceptable); one growing with scale is a communication penalty (a
    pathway misconfiguration).  ``overheads``: scale -> relative overhead."""
    if len(overheads) < 2:
        return "insufficient-data"
    scales = sorted(overheads)
    lo, hi = overheads[scales[0]], overheads[scales[-1]]
    if abs(lo) < 0.02 and abs(hi) < 0.02:
        return "negligible"
    if lo <= 0 or hi <= 0:
        return "noise-dominated"
    growth = hi / max(lo, 1e-9)
    if growth < 1 + tol and growth > 1 / (1 + tol):
        return "constant-overhead"
    return "scaling-overhead"
