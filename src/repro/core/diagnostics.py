"""Findings → reports: severity policy and human/CI rendering.

The paper's outlook (§8) calls for "automated log parsing to proactively
evaluate debug messages, immediately detecting and correcting suboptimal
transport pathways without requiring user intervention."  This module is
that layer: inspector findings + verify verdicts + manifest diffs are
folded into one report with a CI exit policy.
"""
from __future__ import annotations

from dataclasses import dataclass, field

SEVERITY_ORDER = {"info": 0, "warn": 1, "error": 2}


@dataclass
class Diagnostics:
    findings: list[dict] = field(default_factory=list)

    def extend(self, findings: list[dict], source: str) -> None:
        for f in findings:
            self.findings.append({**f, "source": source})

    def add_verdicts(self, verdicts: list, source: str) -> None:
        for v in verdicts:
            if not v.ok:
                self.findings.append({
                    "severity": "error", "kind": f"verify-{v.kind}",
                    "detail": v.detail, "source": source,
                })

    def add_manifest_diff(self, lines: list[str], source: str) -> None:
        for line in lines:
            sev = "warn" if "(host)" in line or "EXPECTED" in line else "error"
            self.findings.append({
                "severity": sev, "kind": "manifest-drift",
                "detail": line, "source": source,
            })

    @property
    def worst(self) -> str:
        if not self.findings:
            return "ok"
        return max((f["severity"] for f in self.findings),
                   key=lambda s: SEVERITY_ORDER.get(s, 0))

    def gate(self, fail_on: str = "error") -> bool:
        """True = pass.  CI calls this; the paper's 'performance-verified
        image' is one whose diagnostics gate passes on every target site."""
        bar = SEVERITY_ORDER.get(fail_on, 2)
        return all(SEVERITY_ORDER.get(f["severity"], 0) < bar
                   for f in self.findings)

    def render(self) -> str:
        if not self.findings:
            return "diagnostics: clean"
        lines = [f"diagnostics: {len(self.findings)} finding(s), worst={self.worst}"]
        for f in sorted(self.findings,
                        key=lambda f: -SEVERITY_ORDER.get(f["severity"], 0)):
            lines.append(f"  [{f['severity']:5s}] {f.get('kind', '?'):24s} "
                         f"({f.get('source', '?')}) {f.get('detail', '')}")
        return "\n".join(lines)
