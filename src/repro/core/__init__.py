"""The paper's contribution, TPU-native (DESIGN.md §2):

  manifest    — environment encapsulation + late host binding (the image)
  bootstrap   — PMIx-analogue wire-up + init microbenchmark
  inspector   — HLO collective-pathway analysis (debug-log parsing, automated)
  verify      — dual-environment statistical comparison
  diagnostics — findings -> CI gate
  registry    — --arch resolution over the assigned architecture pool
"""
from repro.core.bootstrap import WireUp, init_benchmark, init_distributed
from repro.core.diagnostics import Diagnostics
from repro.core.inspector import TransportReport, hlo_cost, parse_hlo
from repro.core.manifest import HostBinding, Manifest, PortableEnv, diff
from repro.core.registry import all_cells, resolve_arch, resolve_shape
from repro.core.verify import (DualEnvHarness, DualEnvReport,
                               constant_vs_scaling_overhead)

__all__ = [
    "WireUp", "init_benchmark", "init_distributed", "Diagnostics",
    "TransportReport", "hlo_cost", "parse_hlo", "HostBinding", "Manifest",
    "PortableEnv", "diff", "all_cells", "resolve_arch", "resolve_shape",
    "DualEnvHarness", "DualEnvReport", "constant_vs_scaling_overhead",
]
