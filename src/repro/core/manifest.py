"""Environment manifests — the container image of this framework.

The paper's portability claim rests on an *immutable, version-pinned
software environment* whose only site-specific parts (drivers, NICs, GPUs)
are bound at launch.  Our equivalent:

  portable part   — PortableEnv: model/shape/rule-set configs, code +
                    jax/numpy versions, XLA flags, dtype policy.  Hashable;
                    two runs with equal hashes are the same "image".
  host binding    — HostBinding: device kind/count, mesh shape/axes,
                    per-chip peaks.  Attached late (bind()).
  attestation     — after lowering, the HLO fingerprint + collective
                    summary are recorded; re-running on another host with
                    the same portable hash but a different HLO fingerprint
                    is the "container behaves differently on this site"
                    signal the paper detects with microbenchmarks.

Manifests serialize to JSON; ``diff`` explains any mismatch — the Table-1
"toolchain comparison" of the paper, automated.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import platform
import sys
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig, TrainConfig


def _hash(obj: Any) -> str:
    return hashlib.sha256(
        json.dumps(obj, sort_keys=True, default=str).encode()).hexdigest()[:16]


@dataclass(frozen=True)
class PortableEnv:
    """Everything that must be identical across sites."""

    model: dict
    shape: dict
    train: dict
    rules: str
    jax_version: str = ""
    numpy_version: str = ""
    python_version: str = ""
    xla_flags: str = ""
    dtype_policy: str = "bf16-params/f32-master"

    @classmethod
    def capture(cls, model: ModelConfig, shape: ShapeConfig,
                train: TrainConfig | None = None, rules: str = "auto",
                xla_flags: str = "") -> "PortableEnv":
        import os

        return cls(
            model=dataclasses.asdict(model),
            shape=dataclasses.asdict(shape),
            train=dataclasses.asdict(train or TrainConfig()),
            rules=rules,
            jax_version=jax.__version__,
            numpy_version=np.__version__,
            python_version=sys.version.split()[0],
            xla_flags=xla_flags or os.environ.get("XLA_FLAGS", ""),
        )

    @property
    def image_hash(self) -> str:
        return _hash(dataclasses.asdict(self))


@dataclass(frozen=True)
class HostBinding:
    """Site-specific, non-encapsulatable facts (late-bound)."""

    device_kind: str
    n_devices: int
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    platform_: str = ""
    hostname: str = ""
    peak_flops: float = 197e12       # bf16 / chip  (TPU v5e)
    hbm_bw: float = 819e9            # B/s / chip
    ici_bw: float = 50e9             # B/s / link

    @classmethod
    def capture(cls, mesh) -> "HostBinding":
        dev = jax.devices()[0]
        return cls(
            device_kind=dev.device_kind,
            n_devices=mesh.devices.size,
            mesh_shape=tuple(mesh.devices.shape),
            mesh_axes=tuple(mesh.axis_names),
            platform_=dev.platform,
            hostname=platform.node(),
        )


@dataclass
class Manifest:
    portable: PortableEnv
    binding: HostBinding | None = None
    attestation: dict = field(default_factory=dict)

    def bind(self, mesh) -> "Manifest":
        self.binding = HostBinding.capture(mesh)
        return self

    def attest(self, *, hlo_text: str | None = None,
               collectives: dict | None = None,
               cost: dict | None = None) -> "Manifest":
        if hlo_text is not None:
            self.attestation["hlo_fingerprint"] = hashlib.sha256(
                hlo_text.encode()).hexdigest()[:16]
            self.attestation["hlo_bytes"] = len(hlo_text)
        if collectives is not None:
            self.attestation["collectives"] = collectives
        if cost is not None:
            self.attestation["cost"] = cost
        return self

    # ---- serialization ----
    def to_json(self) -> str:
        return json.dumps({
            "image_hash": self.portable.image_hash,
            "portable": dataclasses.asdict(self.portable),
            "binding": dataclasses.asdict(self.binding) if self.binding else None,
            "attestation": self.attestation,
        }, indent=1, default=str)

    @classmethod
    def from_json(cls, text: str) -> "Manifest":
        raw = json.loads(text)
        portable = PortableEnv(**raw["portable"])
        m = cls(portable=portable)
        if raw.get("binding"):
            b = raw["binding"]
            b["mesh_shape"] = tuple(b["mesh_shape"])
            b["mesh_axes"] = tuple(b["mesh_axes"])
            m.binding = HostBinding(**b)
        m.attestation = raw.get("attestation", {})
        return m


def diff(a: Manifest, b: Manifest) -> list[str]:
    """Explain differences between two manifests (paper Table 1, automated).

    Portable-part differences are *environment divergence* (a reproducibility
    bug); binding differences are expected host variation; attestation
    differences under equal portable hashes indicate the binding changed the
    compiled behavior — the thing the paper's microbenchmarks exist to catch.
    """
    out: list[str] = []
    da, db = dataclasses.asdict(a.portable), dataclasses.asdict(b.portable)
    for k in sorted(set(da) | set(db)):
        if da.get(k) != db.get(k):
            out.append(f"portable.{k}: {da.get(k)!r} != {db.get(k)!r}")
    if a.binding and b.binding:
        ba, bb = dataclasses.asdict(a.binding), dataclasses.asdict(b.binding)
        for k in sorted(set(ba) | set(bb)):
            if ba.get(k) != bb.get(k):
                out.append(f"binding.{k}: {ba.get(k)!r} != {bb.get(k)!r} (host)")
    fa = a.attestation.get("hlo_fingerprint")
    fb = b.attestation.get("hlo_fingerprint")
    if fa and fb and fa != fb:
        tag = ("EXPECTED (binding differs)" if out else
               "UNEXPECTED — same env+binding, different program")
        out.append(f"attestation.hlo_fingerprint: {fa} != {fb} [{tag}]")
    return out
