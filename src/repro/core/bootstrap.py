"""Distributed wire-up — the PMIx analogue.

In the paper, containerized MPI ranks resolve endpoints by querying the
host's Slurm-side PMIx server; the container carries its own complete MPI
stack and only the wire-up protocol crosses the boundary.  The JAX
equivalent of that boundary is ``jax.distributed.initialize``: each host
process knows only (coordinator_address, num_processes, process_id) — the
exact PMIx triple — and everything else (device discovery, mesh
construction, GSPMD partitioning) happens inside the "image".

This module provides:
  * WireUp — the endpoint-resolution dataclass + env/Slurm detection
    (``--mpi=pmix`` analogue: SLURM_* variables → wire-up triple);
  * init_distributed() — binds it (no-op single-process, real
    jax.distributed otherwise);
  * init_benchmark() — the ``osu_init`` analogue: wall-clock of
    wire-up + mesh construction + first-collective compile, the costs the
    paper measures in Fig. 1.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass


@dataclass(frozen=True)
class WireUp:
    coordinator: str
    num_processes: int
    process_id: int
    local_device_count: int | None = None

    @classmethod
    def from_env(cls) -> "WireUp":
        """Resolve the wire-up triple the way srun --mpi=pmix publishes it."""
        if "SLURM_NTASKS" in os.environ and int(os.environ["SLURM_NTASKS"]) > 1:
            nodelist = os.environ.get("SLURM_STEP_NODELIST", "localhost")
            head = nodelist.split(",")[0].split("[")[0]
            port = os.environ.get("REPRO_COORD_PORT", "9876")
            return cls(
                coordinator=f"{head}:{port}",
                num_processes=int(os.environ["SLURM_NTASKS"]),
                process_id=int(os.environ.get("SLURM_PROCID", "0")),
            )
        return cls(coordinator="localhost:9876", num_processes=1, process_id=0)

    @property
    def is_distributed(self) -> bool:
        return self.num_processes > 1


def init_distributed(wireup: WireUp | None = None) -> WireUp:
    """Bind the process into the cluster.  Single-process: no-op."""
    import jax

    w = wireup or WireUp.from_env()
    if w.is_distributed:
        jax.distributed.initialize(
            coordinator_address=w.coordinator,
            num_processes=w.num_processes,
            process_id=w.process_id,
            local_device_count=w.local_device_count,
        )
    return w


def init_benchmark(mesh_shape: tuple[int, ...], axes: tuple[str, ...],
                   repeats: int = 3) -> dict:
    """osu_init analogue: time the runtime's transition to a communicable
    state — (1) wire-up/mesh construction (PMIx exchange + fabric
    discovery), (2) first-collective compile (endpoint/transport setup),
    (3) steady-state collective issue."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    out: dict = {"mesh_shape": mesh_shape, "axes": axes}

    from repro.parallel.ctx import mesh_of

    t0 = time.perf_counter()
    mesh = mesh_of(mesh_shape, axes)
    out["mesh_construct_s"] = time.perf_counter() - t0

    n = mesh.devices.size
    x = jnp.arange(n * 128, dtype=jnp.float32).reshape(n, 128)

    def allreduce_sum(v):
        return jax.lax.with_sharding_constraint(
            jnp.broadcast_to(v.sum(axis=0, keepdims=True), v.shape),
            NamedSharding(mesh, P(axes[0])))

    t0 = time.perf_counter()
    xs = jax.device_put(x, NamedSharding(mesh, P(axes[0])))
    fn = jax.jit(allreduce_sum)
    fn(xs).block_until_ready()
    out["first_collective_s"] = time.perf_counter() - t0

    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(xs).block_until_ready()
        times.append(time.perf_counter() - t0)
    out["steady_collective_s"] = min(times)
    out["steady_collective_max_s"] = max(times)
    return out
