"""Architecture/config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs import ALL_ARCHS, SHAPES, applicable_shapes
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, reduced


def resolve_arch(name: str) -> ModelConfig:
    if name in ALL_ARCHS:
        return ALL_ARCHS[name]
    # tolerate module-style ids (dots/dashes vs underscores)
    norm = name.replace("_", "-")
    for k in ALL_ARCHS:
        if k.replace(".", "-") == norm or k == norm:
            return ALL_ARCHS[k]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ALL_ARCHS)}")


def resolve_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[str, str]]:
    """Every assignment cell (arch, shape), skips included as per DESIGN §6."""
    cells = []
    for arch in ALL_ARCHS:
        for shape in applicable_shapes(arch):
            cells.append((arch, shape))
    return cells


def smoke_config(name: str) -> ModelConfig:
    return reduced(resolve_arch(name))
