"""Deterministic synthetic token pipeline with host sharding + prefetch.

Production shape: each host reads only its shard of the global batch
(host-data-parallel), batches are derived deterministically from
(seed, step) so a restarted job resumes byte-identically mid-epoch without
any shared iterator state — the data-side requirement for the
checkpoint/restart protocol.  A background thread keeps ``prefetch`` steps
ready so transient host stalls don't reach the collective (see
runtime/straggler.py).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _batch_for_step(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Deterministic (seed, step, host) -> token block.  Zipf-ish marginal
    over the vocab so losses behave like text rather than uniform noise."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    b, s, v = cfg.host_batch, cfg.seq_len, cfg.vocab_size
    # smooth power-law ranks
    u = rng.random((b, s + 1))
    ranks = np.minimum((u ** -1.25 - 1).astype(np.int64), v - 1)
    tokens = ranks.astype(np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class DataPipeline:
    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = _batch_for_step(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> tuple[int, dict[str, np.ndarray]]:
        return self._q.get()

    def __iter__(self):
        return self

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2)


def batch_at(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Random access (resume verification, tests)."""
    return _batch_for_step(cfg, step)
