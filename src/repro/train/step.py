"""Train-step factory: loss → grads → AdamW, with optional gradient
accumulation (microbatching) and int8 error-feedback gradient compression.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
function; binding to a mesh happens in the launcher (launch/train.py,
launch/dryrun.py) via the shard context + NamedShardings — the step itself
is portable across bindings (the paper's image/host split).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models.model import Model
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState


def abstract_train_state(model: Model) -> TrainState:
    ap = model.abstract_params()
    return TrainState(params=ap, opt=adamw.abstract_state(ap))


def init_train_state(model: Model, key: jax.Array) -> TrainState:
    params = model.init_params(key)
    return TrainState(params=params, opt=adamw.init(params))


def make_train_step(model: Model, run: RunConfig) -> Callable:
    tc = run.train

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=tc.remat, z_loss=tc.z_loss)

    def compute_grads(params, batch):
        if tc.microbatches and tc.microbatches > 1:
            n = tc.microbatches
            b = batch["tokens"].shape[0] if "tokens" in batch else (
                batch["token"].shape[0])
            assert b % n == 0, (b, n)
            micro = jax.tree.map(
                lambda x: x.reshape((n, b // n) + x.shape[1:]), batch)

            def acc_step(carry, mb):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc, l_acc = carry
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n, g_acc, grads)
                return (g_acc, l_acc + loss / n), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = jax.lax.scan(
                acc_step, (g0, jnp.zeros((), jnp.float32)), micro)
            metrics = jax.tree.map(lambda x: x[-1], metrics)
            return (loss, metrics), grads

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return (loss, metrics), grads

    def train_step(state: TrainState, batch: dict):
        (loss, metrics), grads = compute_grads(state.params, batch)
        if tc.grad_compress == "int8_ef":
            from repro.optim.compress import compress_decompress
            grads = compress_decompress(grads)
        params, opt, opt_metrics = adamw.apply(tc, state.opt, grads,
                                               state.params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params, opt), metrics

    return train_step
