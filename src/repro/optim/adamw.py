"""AdamW with fp32 master weights, sharded identically to the parameters
(ZeRO-3 falls out of the param partition specs), global-norm clipping and a
warmup+cosine schedule.  No optax dependency (not installed here)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    step: jax.Array          # i32 scalar
    master: Any              # fp32 copies of params
    m: Any
    v: Any


def init(params: Any) -> OptState:
    # copy=True: an already-fp32 param must not alias its master copy
    # (double-donation otherwise).
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def abstract_state(abstract_params: Any) -> OptState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        master=jax.tree.map(f32, abstract_params),
        m=jax.tree.map(f32, abstract_params),
        v=jax.tree.map(f32, abstract_params),
    )


def schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads: Any, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def apply(cfg: TrainConfig, state: OptState, grads: Any,
          param_dtypes: Any) -> tuple[Any, OptState, dict[str, jax.Array]]:
    """One AdamW update.  Returns (new bf16 params, new state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(master, m, v):
        mh = m / c1
        vh = v / c2
        return master - lr * (mh / (jnp.sqrt(vh) + eps) + wd * master)

    new_master = jax.tree.map(upd, state.master, new_m, new_v)
    new_params = jax.tree.map(lambda mast, p: mast.astype(p.dtype),
                              new_master, param_dtypes)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_master, new_m, new_v), metrics
