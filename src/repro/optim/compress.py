"""int8 gradient compression (per-tensor scale) — a distributed-optimization
knob for cross-pod DP traffic.  At the XLA level the win is realized by
all-reducing int8 tensors; in this (single-program GSPMD) framework we model
it as quantize→dequantize around the reduction point, which both halves the
collective bytes when placed pre-reduce and preserves the optimizer math.
Error feedback is carried in the optimizer's m buffer implicitly (the
quantization error is re-seen next step through the loss)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _q(g: jax.Array) -> jax.Array:
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_decompress(grads: Any) -> Any:
    return jax.tree.map(_q, grads)
