from repro.optim import adamw
from repro.optim.adamw import OptState

__all__ = ["adamw", "OptState"]
