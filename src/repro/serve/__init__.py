"""Serving subsystem: paged KV cache, scheduler, engines, and the
request-lifecycle API.

- ``api``: the unified serving contract — ``SamplingParams`` (greedy /
  temperature / top-k / top-p with counter-based per-request PRNG),
  ``RequestHandle`` (streaming, ``result()``, ``cancel()``), the
  ``Engine`` protocol (``submit / step / drain / cancel / report``) and
  the ``run_requests`` compatibility shim.
- ``paging``: BlockAllocator / PrefixCache / KVPool / DevicePageView /
  HostSwapPool (page-level memory; the device view is the page pool +
  per-slot page tables the Pallas paged-attention kernel consumes
  directly, and the host swap pool is the tier below it — preempted
  requests and cold prefix pages park there instead of being dropped).
- ``scheduler``: FCFS + priority admission with preemption-on-OOM.
- ``engine``: ServeEngine (contiguous oracle) and PagedServeEngine
  (prefix caching + chunked prefill), tied together by
  ``compare_engines`` — the dual-environment correctness verdict,
  greedy and sampled.
- ``workloads``: deterministic, seedable workload-trace generator —
  shared-prefix families (multi-tenant chat, RAG, agent loops) crossed
  with arrival processes (uniform, bursty, diurnal, heavy-tail),
  emitting the ``Request`` shapes ``Engine.submit`` accepts.
- ``cluster``: ClusterEngine — N PagedServeEngine replicas behind the
  same ``Engine`` contract, routed by prefix affinity with load-aware
  spill (policies: ``affinity`` / ``round_robin`` / ``random``).
"""
from repro.serve.api import (GREEDY, Engine, LaneState, RequestHandle,
                             SamplingParams, run_requests)
from repro.serve.cluster import (AffinityPolicy, BloomSummary, ClusterEngine,
                                 ExactSummary, RandomPolicy, RoundRobinPolicy,
                                 make_policy, match_depth)
from repro.serve.engine import (PagedServeEngine, Request, ServeEngine,
                                compare_engines, token_matrix)
from repro.serve.paging import (BlockAllocator, BlockAllocatorError,
                                DevicePageView, HostSwapPool, KVPool,
                                PrefixCache, SwapStats, chain_hashes,
                                pages_for)
from repro.serve.scheduler import Plan, SchedEntry, Scheduler, SwapCostModel
from repro.serve.workloads import (WorkloadSpec, WorkloadTrace, generate,
                                   smoke_specs)

__all__ = [
    "AffinityPolicy", "BlockAllocator", "BlockAllocatorError",
    "BloomSummary", "ClusterEngine", "DevicePageView", "Engine",
    "ExactSummary", "GREEDY", "HostSwapPool", "KVPool", "LaneState",
    "PrefixCache", "PagedServeEngine", "Plan", "RandomPolicy", "Request",
    "RequestHandle", "RoundRobinPolicy", "SamplingParams", "SchedEntry",
    "Scheduler", "ServeEngine", "SwapCostModel", "SwapStats",
    "WorkloadSpec", "WorkloadTrace", "chain_hashes", "compare_engines",
    "generate", "make_policy", "match_depth", "pages_for", "run_requests",
    "smoke_specs", "token_matrix",
]
