"""Serving subsystem: paged KV cache, scheduler, and engines.

- ``paging``: BlockAllocator / PrefixCache / KVPool (page-level memory).
- ``scheduler``: FCFS + priority admission with preemption-on-OOM.
- ``engine``: ServeEngine (contiguous oracle) and PagedServeEngine
  (prefix caching + chunked prefill), tied together by
  ``compare_engines`` — the dual-environment correctness verdict.
"""
from repro.serve.engine import (PagedServeEngine, Request, ServeEngine,
                                compare_engines, token_matrix)
from repro.serve.paging import (BlockAllocator, BlockAllocatorError, KVPool,
                                PrefixCache, chain_hashes, pages_for)
from repro.serve.scheduler import Plan, SchedEntry, Scheduler

__all__ = [
    "BlockAllocator", "BlockAllocatorError", "KVPool", "PrefixCache",
    "PagedServeEngine", "Plan", "Request", "SchedEntry", "Scheduler",
    "ServeEngine", "chain_hashes", "compare_engines", "pages_for",
    "token_matrix",
]
