"""Unified request-lifecycle serving API.

Every caller — launchers, examples, benchmarks, the audit pipeline —
speaks one contract to either serving backend, the way the paper's
container interface hides backend divergence behind a single stable
user-facing surface:

- ``SamplingParams``: per-request decoding policy (greedy, temperature,
  top-k, top-p).  Sampled decoding is *counter-based*: the PRNG key for a
  request's ``step``-th output token is derived purely from
  ``(seed, request_id, step)``, never from engine state — so a stream is
  deterministic and replayable across engines, slots, schedules, and
  preemption/recompute cycles (re-running a step re-derives the same key;
  there is no generator state to advance or restore).
- ``RequestHandle``: one submitted request's lifecycle — a streaming
  token iterator, ``result()`` to drain to completion, and ``cancel()``
  (mid-prefill or mid-decode; the engine releases the slot, pages, and
  prefix-cache references).
- ``Engine``: the structural protocol both ``ServeEngine`` and
  ``PagedServeEngine`` implement — ``submit / step / drain / cancel /
  has_work / report``.  The two incompatible seed ``run()`` shapes are
  retired behind the ``run_requests`` compatibility shim.
- ``LaneState``: the host-side mirror of per-slot sampling state handed
  to the jitted fused decode+sample step (``models.decode.
  sample_from_logits``) — fixed ``[slots]`` arrays, so sampling adds no
  shape polymorphism and no recompiles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator, Protocol, runtime_checkable

import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding policy.

    ``temperature <= 0`` selects greedy argmax (the default, and the
    oracle-gated legacy behaviour).  ``top_k <= 0`` means no k-limit;
    ``top_p`` is the nucleus bound in ``(0, 1]``.  ``seed`` roots the
    counter-based key derivation — two requests with the same seed but
    different request ids draw decorrelated streams, the same
    (seed, rid) replays the identical stream anywhere.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0 <= self.seed < 2**31:
            # the seed rides an int32 lane array into the jitted step;
            # fail at construction, not mid-serve (fold a wider hash
            # down before passing it in)
            raise ValueError(f"seed must be in [0, 2**31), got {self.seed}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def describe(self) -> str:
        """Compact trace-payload form (deterministic, replay-comparable)."""
        if self.greedy:
            return "greedy"
        return (f"t={self.temperature:g},k={self.top_k},"
                f"p={self.top_p:g},seed={self.seed}")


GREEDY = SamplingParams()


class LaneState:
    """Per-slot sampling state mirrored into the jitted step.

    Fixed-shape ``[slots]`` arrays (the jit signature never changes with
    the request mix).  ``step`` is the index of the output token about to
    be sampled — because keys are pure functions of (seed, rid, step),
    lanes whose sampled token is discarded (mid-prefill chunks, idle
    slots, recompute after preemption) consume nothing: the stream has no
    state to advance.
    """

    def __init__(self, slots: int):
        self.rid = np.zeros((slots,), np.int32)
        self.step = np.zeros((slots,), np.int32)
        self.seed = np.zeros((slots,), np.int32)
        self.temperature = np.zeros((slots,), np.float32)
        self.top_k = np.zeros((slots,), np.int32)
        self.top_p = np.ones((slots,), np.float32)

    def set(self, slot: int, req: Any) -> None:
        sp = req.sampling or GREEDY
        self.rid[slot] = req.rid
        self.step[slot] = len(req.out)
        self.seed[slot] = sp.seed
        self.temperature[slot] = sp.temperature
        self.top_k[slot] = sp.top_k
        self.top_p[slot] = sp.top_p

    def clear(self, slot: int) -> None:
        self.rid[slot] = 0
        self.step[slot] = 0
        self.seed[slot] = 0
        self.temperature[slot] = 0.0
        self.top_k[slot] = 0
        self.top_p[slot] = 1.0

    def as_args(self) -> dict[str, np.ndarray]:
        return {"rid": self.rid, "step": self.step, "seed": self.seed,
                "temperature": self.temperature, "top_k": self.top_k,
                "top_p": self.top_p}


# ================================================================ protocol


@runtime_checkable
class Engine(Protocol):
    """The common serving contract.  ``submit`` registers a request (it
    starts no work) and returns its handle; ``step`` advances the engine
    by one scheduling tick + one batched model call and returns requests
    finishing this tick; ``drain`` steps until idle; ``cancel`` releases
    a request at any lifecycle stage; ``report`` is the engine's
    machine-readable counters (audit evidence)."""

    def submit(self, req: Any, *, arrival: float | None = None
               ) -> "RequestHandle": ...

    def step(self) -> list: ...

    def drain(self) -> list: ...

    def cancel(self, handle: "RequestHandle") -> bool: ...

    def has_work(self) -> bool: ...

    def report(self) -> dict: ...


class RequestHandle:
    """One submitted request's lifecycle, bound to its engine.

    Iterating the handle streams tokens as the engine produces them
    (pulling ``engine.step()`` under the hood, which also advances every
    other active request — streaming one handle starves nobody).
    """

    def __init__(self, engine: Engine, req: Any, entry: Any = None):
        self.engine = engine
        self.req = req
        self.entry = entry          # scheduler entry (paged engine only)
        self._cursor = 0

    # ------------------------------------------------------------- state
    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def cancelled(self) -> bool:
        return self.req.cancelled

    @property
    def finished(self) -> bool:
        return self.req.finished

    @property
    def done(self) -> bool:
        return self.req.finished or self.req.cancelled

    # --------------------------------------------------------- streaming
    def tokens(self) -> Iterator[int]:
        """Yield output tokens as they are decoded.  Safe to interleave
        with other handles' iteration or ``engine.step()`` calls: the
        cursor only moves forward over ``req.out``."""
        while True:
            out = self.req.out
            while self._cursor < len(out):
                tok = out[self._cursor]
                self._cursor += 1
                yield tok
            if self.done or not self.engine.has_work():
                return
            self.engine.step()

    __iter__ = tokens

    def result(self) -> Any:
        """Drive the engine until this request finishes (or is cancelled);
        returns the underlying request with its full output stream."""
        while not self.done and self.engine.has_work():
            self.engine.step()
        return self.req

    def cancel(self) -> bool:
        """Cancel at any stage (waiting, mid-prefill, mid-decode).  The
        engine releases the slot and every page/prefix-cache reference it
        held.  Returns False if the request already finished."""
        return self.engine.cancel(self)


# ============================================================ compat shim


def run_requests(engine: Engine, requests: list,
                 arrivals: list[float] | None = None) -> list:
    """The retired ``run(list)`` call shape as a thin shim over the
    lifecycle API — one signature for both engines.  Returns requests in
    completion order (cancelled requests never complete and are not
    returned)."""
    for i, req in enumerate(requests):
        engine.submit(req, arrival=arrivals[i] if arrivals else None)
    return engine.drain()
