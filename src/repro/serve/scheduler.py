"""Request scheduler: FCFS within priority, preemption on OOM.

The scheduler is deliberately model-free: it sees abstract entries with a
priority, an arrival time, and a page cost (computed by the engine's cost
function, which nets out prefix-cache hits), and produces a ``Plan`` of
admissions and preemptions.  The engine executes the plan; the clock is
injected so tests drive it with a synthetic timeline and get byte-for-byte
deterministic schedules.

Policy
------
- Admission order: higher priority first, then submission order (FCFS).
  Head-of-line within the sorted order is strict: if the head candidate
  cannot be placed (even after preemption), nothing behind it is admitted
  — this keeps FCFS provable in tests and avoids starving big requests.
- Preemption: a candidate that cannot be placed may evict running entries
  of *strictly lower* priority (lowest priority first, most recently
  submitted first — the cheapest recompute), reclaiming their slot and
  pages.  Preempted entries return to the waiting queue keeping their
  original submission order.  On readmission the engine picks the cheaper
  of two equivalent pathways via ``SwapCostModel``: swap the victim's
  host-parked pages back in, or re-prefill prompt + generated-so-far
  (recompute).  Both yield the token stream of an uninterrupted run —
  greedy decoding is deterministic and sampling keys on
  ``(seed, rid, step)``.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.audit.trace import NULL_TRACER, Tracer

WAITING = "waiting"
RUNNING = "running"
PREEMPTED = "preempted"
DONE = "done"
CANCELLED = "cancelled"


@dataclass
class SchedEntry:
    req: Any
    priority: int = 0          # higher wins
    arrival: float = 0.0       # clock time the request becomes visible
    seq: int = 0               # submission order (FCFS tiebreak)
    state: str = WAITING
    slot: int | None = None
    held_pages: int = 0        # set by the engine at admission
    preemptions: int = 0
    t_admitted: float = 0.0


@dataclass
class Plan:
    admit: list[SchedEntry] = field(default_factory=list)
    preempt: list[SchedEntry] = field(default_factory=list)
    # victim attribution: cand.seq -> the victims picked *for that
    # candidate*.  The engine commits a candidate's preemptions only when
    # its admission actually goes through, so an intra-tick evictability
    # race cannot flush running work for nothing.  ``preempt`` stays the
    # flat aggregate (same entries, plan order).
    victims: dict[int, list[SchedEntry]] = field(default_factory=dict)


@dataclass(frozen=True)
class SwapCostModel:
    """Prices a preempted entry's two readmission pathways in a common
    unit (token-recompute equivalents).

    Restoring swapped pages costs a per-page transfer constant — the
    host->device copy latency expressed in how many tokens could have
    been prefilled in the same time.  Recomputing costs one unit per
    previously-computed token re-prefilled.  Swap wins whenever the
    transfer is cheaper than the prefill it replaces, which for any
    reasonable block size is almost always — except degenerate victims
    preempted with under ``swap_cost_per_page`` tokens written, where
    recompute is genuinely cheaper than the copy.
    """
    swap_cost_per_page: float = 2.0
    recompute_cost_per_token: float = 1.0

    def restore_cost(self, pages: int) -> float:
        return self.swap_cost_per_page * pages

    def recompute_cost(self, tokens: int) -> float:
        return self.recompute_cost_per_token * tokens

    def prefer_swap(self, pages: int, tokens: int) -> bool:
        return self.restore_cost(pages) <= self.recompute_cost(tokens)


@dataclass
class SchedStats:
    admissions: int = 0
    preemptions: int = 0
    readmissions: int = 0
    cancellations: int = 0


class Scheduler:
    def __init__(self, *, slots: int,
                 clock: Callable[[], float] | None = None,
                 tracer: Tracer | None = None,
                 preemption: bool = True):
        self.slots = slots
        self.clock = clock or time.perf_counter
        self.trace = tracer or NULL_TRACER
        # preemption=False models a misconfigured scheduler: no running
        # entry is ever evicted, so a priority burst queues behind
        # long-running work — output streams are unchanged (admission
        # order still sorts by priority; deterministic sampling is
        # schedule-invariant) but tail TTFT inflates under overload.
        # The audit's quantile SLO expectations exist to catch this.
        self.preemption = preemption
        self._seq = itertools.count()
        self.waiting: list[SchedEntry] = []
        self.running: list[SchedEntry] = []
        self.stats = SchedStats()

    # ------------------------------------------------------------ intake
    def submit(self, req: Any, *, priority: int = 0,
               arrival: float | None = None) -> SchedEntry:
        e = SchedEntry(req=req, priority=priority,
                       arrival=self.clock() if arrival is None else arrival,
                       seq=next(self._seq))
        self.waiting.append(e)
        return e

    @property
    def pending(self) -> int:
        return len(self.waiting)

    @property
    def active(self) -> int:
        return len(self.running)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------ planning
    def schedule(self, *, free_slots: int, free_pages: int,
                 cost_fn: Callable[[SchedEntry], int]) -> Plan:
        """One planning pass.  ``free_pages`` should include pages the
        engine can reclaim from the prefix cache (evictable); ``cost_fn``
        returns net new pages an entry needs if admitted now."""
        now = self.clock()
        plan = Plan()
        ready = sorted((e for e in self.waiting if e.arrival <= now),
                       key=lambda e: (-e.priority, e.seq))
        # victim pool: lowest priority first, most recent first
        victims = (sorted(self.running, key=lambda e: (e.priority, -e.seq))
                   if self.preemption else [])
        for cand in ready:
            need = cost_fn(cand)
            # tentative victim picks: committed only if they buy admission
            picked: list[SchedEntry] = []
            slots_if, pages_if = free_slots, free_pages
            while (slots_if <= 0 or pages_if < need) and victims:
                v = victims[0]
                if v.priority >= cand.priority:
                    break  # never preempt equal-or-higher priority
                victims.pop(0)
                picked.append(v)
                slots_if += 1
                pages_if += v.held_pages
            if slots_if > 0 and pages_if >= need:
                plan.preempt.extend(picked)
                if picked:
                    plan.victims[cand.seq] = picked
                plan.admit.append(cand)
                free_slots, free_pages = slots_if - 1, pages_if - need
            else:
                victims = picked + victims   # un-pick: admission failed
                break  # strict head-of-line: preserve FCFS order
        return plan

    # ------------------------------------------------------- state changes
    def mark_running(self, e: SchedEntry, slot: int, held_pages: int) -> None:
        readmit = e.state == PREEMPTED
        if readmit:
            self.stats.readmissions += 1
        self.waiting.remove(e)
        self.running.append(e)
        e.state, e.slot, e.held_pages = RUNNING, slot, held_pages
        e.t_admitted = self.clock()
        self.stats.admissions += 1
        self.trace.emit("sched-readmit" if readmit else "sched-admit",
                        rid=getattr(e.req, "rid", None),
                        seq=e.seq, priority=e.priority, slot=slot,
                        held_pages=held_pages,
                        wait=e.t_admitted - e.arrival)

    def mark_preempted(self, e: SchedEntry) -> None:
        self.running.remove(e)
        self.waiting.append(e)
        self.trace.emit("sched-preempt", rid=getattr(e.req, "rid", None),
                        seq=e.seq, priority=e.priority,
                        slot=e.slot, released_pages=e.held_pages)
        e.state, e.slot, e.held_pages = PREEMPTED, None, 0
        e.preemptions += 1
        self.stats.preemptions += 1

    def mark_done(self, e: SchedEntry) -> None:
        self.running.remove(e)
        e.state, e.slot, e.held_pages = DONE, None, 0
        self.trace.emit("sched-done", rid=getattr(e.req, "rid", None),
                        seq=e.seq, priority=e.priority)

    def mark_cancelled(self, e: SchedEntry) -> None:
        """Drop an entry at any pre-DONE stage.  The engine releases the
        slot and pages before calling this; the scheduler just forgets the
        entry (a cancelled entry never re-enters the waiting queue)."""
        if e.state == RUNNING:
            self.running.remove(e)
        elif e.state in (WAITING, PREEMPTED):
            self.waiting.remove(e)
        else:
            raise ValueError(f"cannot cancel entry in state {e.state!r}")
        was = e.state
        e.state, e.slot, e.held_pages = CANCELLED, None, 0
        self.stats.cancellations += 1
        self.trace.emit("sched-cancel", rid=getattr(e.req, "rid", None),
                        seq=e.seq, priority=e.priority, was=was)
