"""Paged KV cache: block allocator, prefix cache, and physical page pool.

vLLM-style memory management for the serving engine, sized for the
framework's fixed-shape decode path:

- ``BlockAllocator`` hands out fixed-size logical pages from a free list
  and refcounts them so pages can be *shared* between requests (and with
  the prefix cache) without copies.  Double-free and unknown-block frees
  raise — the allocator is the invariant-bearing layer the property tests
  hammer.
- ``PrefixCache`` maps hash-chained token blocks to pages holding their
  KV, so requests with a shared prompt prefix reuse the pages instead of
  recomputing prefill.  Registered pages are immutable; readers hold a
  refcount (copy-on-write at page granularity: writers always write into
  freshly allocated pages).
- ``KVPool`` is the physical storage for registered pages on the
  *gather* pathway — host numpy arrays of shape ``(layers, num_blocks,
  block_size, kv_heads, head_dim)`` per k/v, written once at
  registration and gathered at admission.
- ``DevicePageView`` is the *kernel* pathway's storage: the page pool as
  device arrays plus per-slot page tables, consumed directly by the
  Pallas paged-attention kernel — KV is written and attended through
  the table, prefix sharing is pure metadata, and no dense per-slot
  working cache exists.
- ``HostSwapPool`` is the host swap tier below the device pool:
  preempted requests swap their written pages out instead of discarding
  them (readmission swaps them back in, no re-prefill), and cold prefix
  pages evicted under pressure spill here so ``PrefixCache.match`` can
  page them back in.

Paging governs *admission* (prefix reuse), *capacity* (page accounting +
preemption-on-OOM), *sharing* (refcounts), and *residency* (device vs
host tier) on both pathways.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


class BlockAllocatorError(RuntimeError):
    """Raised on allocator misuse (double free, unknown block, OOM)."""


@dataclass
class BlockStats:
    allocs: int = 0
    frees: int = 0
    peak_in_use: int = 0
    oom_events: int = 0


class BlockAllocator:
    """Fixed-size page allocator with refcounted sharing.

    Blocks are integers in ``[0, num_blocks)``.  ``alloc`` returns a block
    with refcount 1; ``incref`` adds a reader; ``decref`` releases one
    reference and returns the block to the free list when the count hits
    zero.  All misuse raises ``BlockAllocatorError``.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError((num_blocks, block_size))
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list: recently freed pages are reused first (warm rows)
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._ref: dict[int, int] = {}
        self.stats = BlockStats()

    # ------------------------------------------------------------ queries
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    # ------------------------------------------------------------ lifecycle
    def alloc(self) -> int:
        if not self._free:
            self.stats.oom_events += 1
            raise BlockAllocatorError("out of pages")
        bid = self._free.pop()
        self._ref[bid] = 1
        self.stats.allocs += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        return bid

    def incref(self, bid: int) -> None:
        if bid not in self._ref:
            raise BlockAllocatorError(f"incref on unallocated block {bid}")
        self._ref[bid] += 1

    def decref(self, bid: int) -> None:
        ref = self._ref.get(bid)
        if ref is None:
            raise BlockAllocatorError(f"free of unallocated block {bid}")
        if ref <= 0:  # pragma: no cover - guarded by deletion below
            raise BlockAllocatorError(f"double free of block {bid}")
        self._ref[bid] = ref - 1
        if self._ref[bid] == 0:
            del self._ref[bid]
            self._free.append(bid)
            self.stats.frees += 1

    def check(self) -> None:
        """Invariant audit: every block is either free or refcounted ≥ 1."""
        assert len(self._free) + len(self._ref) == self.num_blocks
        assert all(r >= 1 for r in self._ref.values())
        assert len(set(self._free)) == len(self._free)
        assert not (set(self._free) & set(self._ref))


# ================================================================= hashing


def chain_hashes(tokens: Sequence[int], block_size: int) -> list[int]:
    """Hash chain over full token blocks: ``h_i = H(h_{i-1}, block_i)``.

    Only complete blocks participate (partial tails are never cached), so
    two prompts share cache entries exactly up to their common full-block
    prefix.  blake2b/8-byte digests keep collisions negligible at serving
    scale while staying deterministic across processes.
    """
    out: list[int] = []
    prev = 0
    for start in range(0, (len(tokens) // block_size) * block_size,
                       block_size):
        block = tokens[start:start + block_size]
        h = hashlib.blake2b(
            np.asarray([prev, *block], dtype=np.uint64).tobytes(),
            digest_size=8)
        prev = int.from_bytes(h.digest(), "little")
        out.append(prev)
    return out


@dataclass
class PrefixStats:
    lookups: int = 0
    hit_blocks: int = 0
    miss_blocks: int = 0
    insertions: int = 0
    evictions: int = 0
    hit_tokens: int = 0
    spills: int = 0      # cold pages copied to the host tier at eviction
    restores: int = 0    # spilled pages paged back in on a match

    @property
    def hit_rate(self) -> float:
        total = self.hit_blocks + self.miss_blocks
        return self.hit_blocks / total if total else 0.0


class PrefixCache:
    """Content-addressed map from token-block hash chains to pages.

    The cache holds one reference on every registered page (so pages
    survive their writer's completion); ``match`` adds one reference per
    matched page on behalf of the caller.  Pages whose only reference is
    the cache's own are *evictable* — ``evict`` reclaims them LRU-first
    under allocator pressure.
    """

    def __init__(self, allocator: BlockAllocator):
        self.allocator = allocator
        self._map: OrderedDict[int, int] = OrderedDict()  # chain hash -> bid
        self.stats = PrefixStats()
        # cold-page spill tier (armed via attach_spill): hash -> host id,
        # LRU order.  Spilled pages hold host storage only — no device page.
        self._spilled: OrderedDict[int, int] = OrderedDict()
        self._spill_cap = 0
        self._spill_out = None   # bid -> host id | None
        self._page_in = None     # host id -> device bid | None
        self._drop = None        # host id -> None

    def __len__(self) -> int:
        return len(self._map)

    @property
    def spilled(self) -> int:
        """Spilled (host-resident) page count."""
        return len(self._spilled)

    def attach_spill(self, *, spill_out, page_in, drop,
                     capacity: int) -> None:
        """Arm the cold-page spill tier.

        ``spill_out(bid)`` copies a device page's rows to host storage and
        returns a host id (None = tier full, the page is simply dropped);
        ``page_in(host_id)`` allocates a device page, copies the rows back
        and returns the new bid with one reference — the cache's own —
        (None = no device page free, the match stops there); ``drop``
        releases host storage.  ``capacity`` bounds the spilled set,
        oldest entries dropped first.
        """
        self._spill_out, self._page_in, self._drop = spill_out, page_in, drop
        self._spill_cap = capacity

    # ------------------------------------------------------------- lookup
    def match(self, tokens: Sequence[int], *,
              max_tokens: int | None = None) -> tuple[int, list[int]]:
        """Longest cached prefix of ``tokens``: ``(n_tokens, block_ids)``.

        Caller owns one reference per returned block (release via
        ``allocator.decref``).  ``max_tokens`` caps the match so callers
        can keep at least one token to feed through the model.
        """
        bs = self.allocator.block_size
        self.stats.lookups += 1
        bids: list[int] = []
        for h in chain_hashes(tokens, bs):
            if max_tokens is not None and (len(bids) + 1) * bs > max_tokens:
                break
            bid = self._map.get(h)
            if bid is None:
                bid = self._restore(h)
            if bid is None:
                self.stats.miss_blocks += 1
                break
            self._map.move_to_end(h)  # LRU touch
            self.allocator.incref(bid)
            bids.append(bid)
            self.stats.hit_blocks += 1
        self.stats.hit_tokens += len(bids) * bs
        return len(bids) * bs, bids

    def peek(self, tokens: Sequence[int], *,
             max_tokens: int | None = None) -> int:
        """Matched-token count without taking references (for cost models)."""
        bs = self.allocator.block_size
        n = 0
        for h in chain_hashes(tokens, bs):
            if max_tokens is not None and n + bs > max_tokens:
                break
            if h not in self._map:
                break
            n += bs
        return n

    def _restore(self, h: int) -> int | None:
        """Page a spilled entry back onto the device (None if impossible).

        The restore consumes one free device page; the caller's admission
        arithmetic stays consistent because the restored page joins the
        match's shared list, reducing ``need`` by exactly the page the
        restore consumed.  A failed page-in (device OOM) leaves the entry
        spilled — the match simply stops at the resident prefix.
        """
        hid = self._spilled.get(h)
        if hid is None or self._page_in is None:
            return None
        bid = self._page_in(hid)
        if bid is None:
            return None
        del self._spilled[h]
        self._drop(hid)           # the device copy is authoritative again
        self._map[h] = bid        # page_in's reference becomes the cache's
        self.stats.restores += 1
        return bid

    def chains(self) -> tuple[int, ...]:
        """The resident chain hashes, LRU order (coldest first).  This is
        the cluster router's per-replica summary feed: a replica whose
        cache holds a request's leading chain hashes can serve its prefix
        from pages instead of recomputing it."""
        return tuple(self._map)

    # ----------------------------------------------------------- register
    def contains(self, chain_hash: int) -> bool:
        return chain_hash in self._map

    def insert(self, chain_hash: int, bid: int) -> bool:
        """Register a page under its chain hash.  The cache takes its own
        reference.  Returns False (no ref taken) if the hash is already
        registered — first writer wins, the loser keeps its private page."""
        if chain_hash in self._map:
            return False
        stale = self._spilled.pop(chain_hash, None)
        if stale is not None:     # fresh device copy supersedes the spill
            self._drop(stale)
        self.allocator.incref(bid)
        self._map[chain_hash] = bid
        self.stats.insertions += 1
        return True

    # ------------------------------------------------------------ evict
    def evictable(self) -> int:
        return sum(1 for bid in self._map.values()
                   if self.allocator.refcount(bid) == 1)

    def evict(self, n_blocks: int) -> int:
        """Drop up to ``n_blocks`` pages held only by the cache, LRU first.
        Returns how many were reclaimed.  With a spill tier attached the
        cold page's rows are copied to host storage first, so a later
        ``match`` on its chain hash can page it back in instead of
        re-prefilling."""
        reclaimed = 0
        for h in list(self._map):
            if reclaimed >= n_blocks:
                break
            bid = self._map[h]
            if self.allocator.refcount(bid) == 1:
                if self._spill_out is not None:
                    hid = self._spill_out(bid)
                    if hid is not None:
                        self._spilled[h] = hid
                        self.stats.spills += 1
                        while len(self._spilled) > self._spill_cap:
                            _, old = self._spilled.popitem(last=False)
                            self._drop(old)
                del self._map[h]
                self.allocator.decref(bid)
                self.stats.evictions += 1
                reclaimed += 1
        return reclaimed


# ================================================================= storage


class KVPool:
    """Physical page storage for registered prefix KV (host memory).

    One (k, v) row-block per page: ``(layers, block_size, kv, hd)``.
    Written once at registration; gathered into a slot's dense working
    cache at admission.  Host numpy keeps the pool off the device and the
    jitted decode step's shapes fixed.
    """

    def __init__(self, num_blocks: int, block_size: int, layers: int,
                 n_kv: int, head_dim: int, dtype):
        shape = (layers, num_blocks, block_size, n_kv, head_dim)
        self.k = np.zeros(shape, dtype=dtype)
        self.v = np.zeros(shape, dtype=dtype)
        self.block_size = block_size

    def write(self, bid: int, k_rows: np.ndarray, v_rows: np.ndarray) -> None:
        """k_rows/v_rows: (layers, block_size, kv, hd)."""
        self.k[:, bid] = k_rows
        self.v[:, bid] = v_rows

    def read(self, bids: Sequence[int]) -> tuple[np.ndarray, np.ndarray]:
        """Gather pages -> (layers, len(bids)*block_size, kv, hd)."""
        idx = np.asarray(list(bids), dtype=np.int64)
        k = self.k[:, idx]  # (L, n, bs, kv, hd)
        v = self.v[:, idx]
        n = idx.shape[0] * self.block_size
        return (k.reshape(k.shape[0], n, *k.shape[3:]),
                v.reshape(v.shape[0], n, *v.shape[3:]))


@dataclass
class SwapStats:
    swap_out_pages: int = 0   # pages copied device -> host
    swap_in_pages: int = 0    # pages copied host -> device
    dropped_pages: int = 0    # host pages released without a swap-in
    peak_in_use: int = 0


class HostSwapPool:
    """Host-memory swap tier for device KV pages.

    The second rung of the KV memory hierarchy: preempted requests park
    their written pages here instead of discarding them (readmission swaps
    them back in, skipping the re-prefill), and cold prefix-cache pages
    evicted under allocator pressure spill here so a later match can page
    them in.  Storage is per-page ``(layers, block_size, kv, hd)`` numpy
    copies keyed by a monotonically increasing host id; entries are
    refcounted like device pages so the property tests can assert the
    tier never leaks.

    ``capacity`` bounds the resident page count; a full tier makes
    ``put`` return ``None`` and the caller falls back to recompute — the
    swap pathway degrades, it never breaks correctness.
    """

    def __init__(self, capacity: int | None, block_size: int):
        if capacity is not None and capacity < 0:
            # capacity 0 is legal: an always-full tier, every put declined
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self.block_size = block_size
        self._store: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._ref: dict[int, int] = {}
        self._next = 0
        self.stats = SwapStats()

    # ------------------------------------------------------------ queries
    @property
    def in_use(self) -> int:
        return len(self._store)

    def refcount(self, hid: int) -> int:
        return self._ref.get(hid, 0)

    # ---------------------------------------------------------- lifecycle
    def put(self, k_rows: np.ndarray, v_rows: np.ndarray) -> int | None:
        """Store one page of KV rows; returns its host id with refcount 1,
        or ``None`` when the tier is at capacity."""
        if self.capacity is not None and len(self._store) >= self.capacity:
            return None
        hid = self._next
        self._next += 1
        self._store[hid] = (np.array(k_rows, copy=True),
                            np.array(v_rows, copy=True))
        self._ref[hid] = 1
        self.stats.swap_out_pages += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        return hid

    def get(self, hid: int) -> tuple[np.ndarray, np.ndarray]:
        if hid not in self._store:
            raise BlockAllocatorError(f"get of unknown host page {hid}")
        return self._store[hid]

    def incref(self, hid: int) -> None:
        if hid not in self._ref:
            raise BlockAllocatorError(f"incref on unknown host page {hid}")
        self._ref[hid] += 1

    def decref(self, hid: int, *, swapped_in: bool = False) -> None:
        ref = self._ref.get(hid)
        if ref is None:
            raise BlockAllocatorError(f"free of unknown host page {hid}")
        self._ref[hid] = ref - 1
        if self._ref[hid] == 0:
            del self._ref[hid]
            del self._store[hid]
            if swapped_in:
                self.stats.swap_in_pages += 1
            else:
                self.stats.dropped_pages += 1

    def check(self) -> None:
        """Invariant audit: storage and refcounts cover the same ids, all
        refcounts positive, capacity respected."""
        assert set(self._store) == set(self._ref)
        assert all(r >= 1 for r in self._ref.values())
        if self.capacity is not None:
            assert len(self._store) <= self.capacity


class DevicePageView:
    """Device-resident page pool + per-slot page tables for the Pallas
    paged-attention kernel (``kernels.paged_attention``).

    The pool arrays ``k``/``v`` — ``(layers, num_blocks, block_size, kv,
    hd)`` — ARE the KV storage on the kernel path: the jitted paged step
    writes fresh rows into them through the page table and attends every
    page through the same table, so prefix sharing is pure metadata (a
    shared page appears in many slots' table rows) and registration
    copies nothing.  The arrays are donated to the step and re-adopted
    from its output each tick (``cache()`` / ``adopt()``).

    ``page_table`` is the host mirror the engine keeps in sync with the
    ``BlockAllocator``: ``bind_slot`` installs a slot's ordered physical
    pages when the allocator hands them out at admission, ``clear_slot``
    zeroes the row when the pages are released (finish / cancel /
    preempt).  Cleared and padding entries hold page 0 — a always-valid
    index the kernel masks by sequence length, never an out-of-bounds
    read.
    """

    def __init__(self, num_blocks: int, block_size: int, layers: int,
                 n_kv: int, head_dim: int, dtype, *, slots: int,
                 max_pages: int):
        import jax.numpy as jnp
        shape = (layers, num_blocks, block_size, n_kv, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self.block_size = block_size
        self.max_pages = max_pages
        self.page_table = np.zeros((slots, max_pages), np.int32)

    # ------------------------------------------------------------- tables
    def bind_slot(self, slot: int, blocks: Sequence[int]) -> None:
        if len(blocks) > self.max_pages:
            raise BlockAllocatorError(
                f"slot {slot}: {len(blocks)} pages exceed the table's "
                f"{self.max_pages}")
        self.page_table[slot] = 0
        self.page_table[slot, :len(blocks)] = blocks

    def clear_slot(self, slot: int) -> None:
        self.page_table[slot] = 0

    # -------------------------------------------------------------- pools
    def cache(self) -> dict:
        """The pool as the jitted step's cache pytree (donated)."""
        return {"paged": {"k": self.k, "v": self.v}}

    def adopt(self, cache: dict) -> None:
        """Re-own the pool arrays returned by the jitted step."""
        self.k = cache["paged"]["k"]
        self.v = cache["paged"]["v"]


def pages_for(n_tokens: int, block_size: int) -> int:
    """Pages needed to hold ``n_tokens`` KV rows."""
    return -(-max(n_tokens, 0) // block_size)
