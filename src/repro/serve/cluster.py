"""Multi-replica cluster serving with prefix-affinity routing.

``ClusterEngine`` implements the ``serve.api.Engine`` protocol over N
``PagedServeEngine`` replicas — one more layer of the same contract, so
every consumer (launchers, benchmarks, the audit pipeline, the
``compare_engines`` oracle) drives a cluster exactly the way it drives a
single engine.

Routing is the new pathway, and it is built to be *verifiable*:

- **Prefix affinity.**  A request is scored against each replica by how
  deep its ``chain_hashes`` prefix chain matches a cheap per-replica
  summary of that replica's ``PrefixCache`` chains (exact hash set or a
  Bloom digest).  Deep match ⇒ the replica can serve the prompt's prefix
  from resident pages instead of recomputing it.  Summaries are refreshed
  from ``report()`` — the counters tell the router when a replica's
  resident set moved, so between refreshes the router may act on a stale
  view (bounded by ``refresh_every`` ticks).
- **Load-aware spill.**  When the affine replica is saturated (in-flight
  requests ≥ ``spill_factor ×`` its slots) the request spills to the
  least-loaded replica: prefix locality is a latency optimisation, not a
  correctness constraint, and queueing behind a hot replica to preserve
  it inverts the trade.
- **Pluggable policy.**  ``affinity`` (the production path),
  ``round_robin`` and ``random`` are interchangeable policy objects, so a
  routing misconfiguration is *injectable*: random routing keeps every
  token stream bit-identical (counter-based sampling is engine- and
  placement-independent) while cratering ``routed_affinity`` and the
  cluster-wide ``shared_hit_rate`` — only the audit layer's
  ``pathway-routing`` expectations separate it from the healthy run.

Every routing decision emits a ``route`` trace event (cluster tracer +
the chosen replica's own tracer), and ``report()`` aggregates replica
counters under the cluster's routing stats, so the pathway the router
took is evidence, not folklore.

Token-exactness by construction: requests are routed whole, each replica
is a full ``PagedServeEngine`` over the same weights, greedy decode is
batch-independent and sampled decode keys on ``(seed, rid, step)`` — so
a cluster of any size produces exactly the single engine's streams.
``compare_engines(..., cluster={...})`` gates this as the oracle verdict.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Sequence

from repro.audit.trace import NULL_TRACER, Tracer
from repro.serve.api import GREEDY, RequestHandle, run_requests
from repro.serve.engine import PagedServeEngine, Request, _validate
from repro.serve.paging import chain_hashes, pages_for

ROUTING_POLICIES = ("affinity", "round_robin", "random")

#: Odd 64-bit mixing constants for the Bloom digest's k probe positions.
_BLOOM_MIX = (0x9E3779B97F4A7C15, 0xC2B2AE3D27D4EB4F, 0x165667B19E3779F9)
_MASK64 = (1 << 64) - 1


class ExactSummary:
    """Per-replica prefix summary as the exact chain-hash set."""

    kind = "exact"

    def __init__(self):
        self._set: set[int] = set()

    def add(self, h: int) -> None:
        self._set.add(h)

    def __contains__(self, h: int) -> bool:
        return h in self._set

    def __len__(self) -> int:
        return len(self._set)


class BloomSummary:
    """Per-replica prefix summary as a Bloom digest: constant size
    regardless of resident-chain count, deterministic probe positions
    (multiplicative mixing of the 64-bit chain hash), and one-sided
    error — false positives cost a misrouted request a cache miss, never
    a wrong token."""

    kind = "bloom"

    def __init__(self, bits: int = 4096, k: int = 3):
        if bits <= 0 or not 1 <= k <= len(_BLOOM_MIX):
            raise ValueError(f"bloom needs bits > 0 and 1 <= k <= "
                             f"{len(_BLOOM_MIX)}, got ({bits}, {k})")
        self.bits = bits
        self.k = k
        self._field = 0
        self._n = 0

    def _positions(self, h: int):
        for mult in _BLOOM_MIX[:self.k]:
            yield ((h * mult) & _MASK64) % self.bits

    def add(self, h: int) -> None:
        for pos in self._positions(h):
            self._field |= 1 << pos
        self._n += 1

    def __contains__(self, h: int) -> bool:
        return all(self._field >> pos & 1 for pos in self._positions(h))

    def __len__(self) -> int:
        return self._n


def _make_summary(kind: str):
    if kind == "exact":
        return ExactSummary()
    if kind == "bloom":
        return BloomSummary()
    raise ValueError(f"summary must be 'exact' or 'bloom', got {kind!r}")


def match_depth(summary, hashes: Sequence[int]) -> int:
    """Leading chain hashes present in the summary — the number of full
    prompt blocks the replica could serve from resident pages."""
    depth = 0
    for h in hashes:
        if h not in summary:
            break
        depth += 1
    return depth


@dataclass
class _Replica:
    """Router-side view of one replica: the engine, its tracer, and the
    (possibly stale) prefix summary last refreshed from ``report()``."""

    idx: int
    engine: PagedServeEngine
    tracer: Tracer
    summary: Any = field(default_factory=ExactSummary)
    # (insertions, evictions) seen at the last refresh: the pair moves
    # monotonically whenever the resident chain set changes, so it is
    # the staleness key the report feed exposes
    feed_key: tuple[int, int] = (-1, -1)

    @property
    def load(self) -> int:
        """In-flight requests (waiting + running) — the spill signal."""
        return self.engine.sched.pending + self.engine.sched.active

    @property
    def slots(self) -> int:
        return self.engine.slots


# ================================================================ policies


class AffinityPolicy:
    """Deepest-prefix-match replica, least-loaded tiebreak, load-aware
    spill: a saturated affine replica (load ≥ ``spill_factor × slots``)
    loses the request to the least-loaded replica."""

    name = "affinity"

    def __init__(self, spill_factor: float = 2.0):
        if spill_factor <= 0:
            raise ValueError(f"spill_factor must be > 0, got {spill_factor}")
        self.spill_factor = spill_factor

    def choose(self, req: Request, depths: Sequence[int],
               replicas: Sequence[_Replica]) -> tuple[int, str]:
        loads = [r.load for r in replicas]
        least = min(range(len(replicas)), key=lambda i: (loads[i], i))
        best = max(depths)
        if best == 0:
            return least, "cold"           # no affinity anywhere: balance
        cands = [i for i, d in enumerate(depths) if d == best]
        idx = min(cands, key=lambda i: (loads[i], i))
        saturated = loads[idx] >= self.spill_factor * replicas[idx].slots
        if saturated and loads[least] < loads[idx]:
            return least, "spill"
        return idx, "affine"


class RoundRobinPolicy:
    """Placement-blind rotation — the locality-free baseline."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def choose(self, req: Request, depths: Sequence[int],
               replicas: Sequence[_Replica]) -> tuple[int, str]:
        idx = self._next % len(replicas)
        self._next += 1
        return idx, "round_robin"


class RandomPolicy:
    """Seeded uniform routing — the injectable misconfiguration: token
    streams stay bit-identical while affinity and the cross-replica
    prefix hit rate crater."""

    name = "random"

    def __init__(self, seed: int = 0):
        import numpy as np
        self._rng = np.random.default_rng(seed)

    def choose(self, req: Request, depths: Sequence[int],
               replicas: Sequence[_Replica]) -> tuple[int, str]:
        return int(self._rng.integers(len(replicas))), "random"


def make_policy(routing, *, seed: int = 0):
    """Resolve a policy name (or pass a policy object through)."""
    if hasattr(routing, "choose") and hasattr(routing, "name"):
        return routing
    if routing == "affinity":
        return AffinityPolicy()
    if routing == "round_robin":
        return RoundRobinPolicy()
    if routing == "random":
        return RandomPolicy(seed)
    raise ValueError(f"routing must be one of {ROUTING_POLICIES} or a "
                     f"policy object, got {routing!r}")


# ================================================================= cluster


@dataclass
class ClusterStats:
    routed: int = 0
    affine_opportunities: int = 0   # routed requests with any summary match
    affine_routed: int = 0          # ... that landed on a deepest-match replica
    spills: int = 0
    cold: int = 0
    cancelled_unrouted: int = 0
    summary_rebuilds: int = 0

    @property
    def routed_affinity(self) -> float:
        """Fraction of affinity opportunities the router converted.  A
        healthy affinity policy sits near 1.0; uniform-random routing
        over n replicas sits near 1/n.  Vacuously 1.0 when the workload
        offered no opportunity (nothing to convert)."""
        if not self.affine_opportunities:
            return 1.0
        return self.affine_routed / self.affine_opportunities


class ClusterEngine:
    """N ``PagedServeEngine`` replicas behind one ``Engine`` contract.

    ``submit`` queues the request at the front door; routing happens when
    the request's arrival tick is due (inside ``step``), against the
    then-current per-replica prefix summaries — exactly when a real
    router would see it.  Each cluster tick routes due arrivals and then
    steps every replica once, so replica tick clocks stay in lockstep
    with the cluster clock and arrival semantics match the single-engine
    run tick for tick.

    Construction kwargs beyond the geometry (``num_blocks``, ``kernel``,
    ``use_prefix_cache``, ``preemption``, ``admit_every``, ...) are
    forwarded to every replica.
    """

    def __init__(self, model, params, *, replicas: int = 2, slots: int = 4,
                 max_len: int = 256, block_size: int = 16, chunk: int = 8,
                 routing="affinity", summary: str = "exact",
                 refresh_every: int = 1, routing_seed: int = 0,
                 tracer: Tracer | None = None,
                 replica_tracers: Sequence[Tracer] | None = None,
                 **engine_kwargs):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if refresh_every < 1:
            raise ValueError(f"refresh_every must be >= 1, got {refresh_every}")
        _make_summary(summary)          # validate the kind eagerly
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.block_size = block_size
        self.chunk = chunk
        self.summary_kind = summary
        self.refresh_every = refresh_every
        self.policy = make_policy(routing, seed=routing_seed)
        self.trace = tracer or NULL_TRACER
        if replica_tracers is None:
            replica_tracers = [Tracer() for _ in range(replicas)]
        if len(replica_tracers) != replicas:
            raise ValueError(f"need {replicas} replica tracers, "
                             f"got {len(replica_tracers)}")
        self._replicas = [
            _Replica(idx=i,
                     engine=PagedServeEngine(
                         model, params, slots=slots, max_len=max_len,
                         block_size=block_size, chunk=chunk,
                         tracer=replica_tracers[i], **engine_kwargs),
                     tracer=replica_tracers[i],
                     summary=_make_summary(summary))
            for i in range(replicas)
        ]
        self.now = 0.0
        self._ticks = 0
        self._pending: list[tuple[float, Request, RequestHandle]] = []
        self._placement: dict[int, tuple[int, RequestHandle]] = {}
        self.cstats = ClusterStats()
        ref = self._replicas[0].engine
        self.trace.emit(
            "engine-init", engine="cluster", replicas=replicas,
            family=model.cfg.family, arch=model.cfg.name,
            routing=self.policy.name, replica_engine="paged",
            slots=slots, max_len=max_len, block_size=block_size,
            chunk=chunk, pages=replicas * ref.alloc.num_blocks,
            prefix_cache=ref.prefix_enabled, kernel=ref.kernel,
            preemption=ref.sched.preemption, summary=summary,
            refresh_every=refresh_every)

    # -------------------------------------------------------------- views
    @property
    def replicas(self) -> list[PagedServeEngine]:
        return [r.engine for r in self._replicas]

    @property
    def replica_tracers(self) -> list[Tracer]:
        return [r.tracer for r in self._replicas]

    # ------------------------------------------------------------ intake
    def submit(self, req: Request, *, arrival: float | None = None
               ) -> RequestHandle:
        # the replica-side static checks, applied at the front door:
        # routing is deferred to the arrival tick, and a request that can
        # never place must fail here, not head-of-line-block a replica
        _validate(req)
        ref = self._replicas[0].engine
        feed = req.prompt[-(self.max_len - req.max_new):]
        worst = pages_for(len(feed) + req.max_new, self.block_size)
        if worst > ref.alloc.num_blocks:
            raise ValueError(
                f"request {req.rid} needs {worst} pages even fully "
                f"recomputed; each replica pool has {ref.alloc.num_blocks}")
        arrival = self.now if arrival is None else arrival
        req.t_submit = req.t_submit or time.perf_counter()
        handle = RequestHandle(self, req)
        self._pending.append((arrival, req, handle))
        self.trace.emit("submit", rid=req.rid, tick=self.now,
                        arrival=arrival, prompt_tokens=len(req.prompt),
                        max_new=req.max_new,
                        sampling=(req.sampling or GREEDY).describe())
        return handle

    def has_work(self) -> bool:
        return bool(self._pending) or any(r.engine.has_work()
                                          for r in self._replicas)

    # ----------------------------------------------------------- summaries
    def _refresh_summaries(self) -> None:
        """Rebuild stale per-replica summaries from the report feed.  The
        report's insertion/eviction counters are the staleness key: when
        they moved since the last refresh the resident chain set changed
        and the digest is rebuilt from ``PrefixCache.chains()``."""
        for r in self._replicas:
            rep = r.engine.report()
            key = (rep["prefix_insertions"], rep["prefix_evictions"])
            if key == r.feed_key:
                continue
            s = _make_summary(self.summary_kind)
            for h in r.engine.prefix.chains():
                s.add(h)
            r.summary = s
            r.feed_key = key
            self.cstats.summary_rebuilds += 1

    # -------------------------------------------------------------- route
    def _route(self, arrival: float, req: Request,
               handle: RequestHandle) -> None:
        feed = req.prompt[-(self.max_len - req.max_new):]
        hashes = chain_hashes(feed, self.block_size)
        depths = [match_depth(r.summary, hashes) for r in self._replicas]
        idx, decision = self.policy.choose(req, depths, self._replicas)
        # affinity accounting is policy-independent: every policy is
        # judged against the same "did it land on a deepest-match
        # replica" yardstick the audit layer gates on
        best = max(depths)
        self.cstats.routed += 1
        if best > 0:
            self.cstats.affine_opportunities += 1
            if depths[idx] == best:
                self.cstats.affine_routed += 1
        if decision == "spill":
            self.cstats.spills += 1
        elif decision == "cold":
            self.cstats.cold += 1
        replica = self._replicas[idx]
        rh = replica.engine.submit(req, arrival=arrival)
        handle.entry = rh.entry
        self._placement[id(req)] = (idx, rh)
        payload = dict(rid=req.rid, tick=self.now, arrival=arrival,
                       replica=idx, policy=self.policy.name,
                       decision=decision, depth=depths[idx],
                       best_depth=best, load=replica.load)
        self.trace.emit("route", **payload)
        if replica.tracer is not self.trace:
            replica.tracer.emit("route", **payload)

    # --------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """One cluster tick: refresh summaries (every ``refresh_every``-th
        tick), route due arrivals in submission order, then step every
        replica once (idle replicas tick too, keeping all clocks in
        lockstep with the cluster clock)."""
        self.now += 1.0
        self._ticks += 1
        if self._pending:
            if (self._ticks - 1) % self.refresh_every == 0:
                self._refresh_summaries()
            still = []
            for item in self._pending:
                if item[0] <= self.now:
                    self._route(*item)
                else:
                    still.append(item)
            self._pending = still
        done: list[Request] = []
        for r in self._replicas:
            done.extend(r.engine.step())
        return done

    def drain(self) -> list[Request]:
        done: list[Request] = []
        while self.has_work():
            done.extend(self.step())
        return done

    # ------------------------------------------------------------ cancel
    def cancel(self, handle: RequestHandle) -> bool:
        req = handle.req
        if req.finished or req.cancelled:
            return False
        placed = self._placement.get(id(req))
        if placed is not None:
            return placed[1].cancel()       # delegate to the replica
        for i, (_, r, _h) in enumerate(self._pending):
            if r is req:
                self._pending.pop(i)
                req.cancelled = True
                req.t_done = time.perf_counter()
                self.cstats.cancelled_unrouted += 1
                self.trace.emit("cancel", rid=req.rid, phase="waiting",
                                tick=self.now, released_pages=0)
                return True
        return False

    # ---------------------------------------------------------- run shim
    def run(self, requests: list[Request],
            arrivals: list[float] | None = None) -> list[Request]:
        return run_requests(self, requests, arrivals)

    # ------------------------------------------------------------- report
    def report(self) -> dict:
        reps = [r.engine.report() for r in self._replicas]
        prefill = sum(rep["prefill_tokens"] for rep in reps)
        cached = sum(rep["cached_tokens"] for rep in reps)
        shared_hit = cached / (prefill + cached) if prefill + cached else 0.0
        kernels = {rep["kernel"] for rep in reps}
        occ = [rep["mean_batch_occupancy"] for rep in reps]
        return {
            "engine": "cluster",
            "replicas": len(self._replicas),
            "replica_engine": "paged",
            "routing": self.policy.name,
            "summary": self.summary_kind,
            "refresh_every": self.refresh_every,
            "served": sum(rep["served"] for rep in reps),
            "cancelled": (sum(rep["cancelled"] for rep in reps)
                          + self.cstats.cancelled_unrouted),
            "decode_steps": sum(rep["decode_steps"] for rep in reps),
            "tokens_out": sum(rep["tokens_out"] for rep in reps),
            "mean_batch_occupancy": round(sum(occ) / len(occ), 2),
            "prefill_tokens": prefill,
            "cached_tokens": cached,
            # cluster-wide (cross-replica) prefix reuse: the router's
            # quality shows up here — misrouting recomputes prefixes a
            # sibling replica already holds
            "prefix_hit_rate": round(shared_hit, 3),
            "shared_hit_rate": round(shared_hit, 3),
            "prefix_chains": sum(rep["prefix_chains"] for rep in reps),
            "pages": sum(rep["pages"] for rep in reps),
            "block_size": self.block_size,
            "chunk": self.chunk,
            "prefix_cache": all(rep["prefix_cache"] for rep in reps),
            "kernel": kernels.pop() if len(kernels) == 1 else "mixed",
            "preemptions": sum(rep["preemptions"] for rep in reps),
            "routed": self.cstats.routed,
            "routed_affinity": round(self.cstats.routed_affinity, 3),
            "affine_opportunities": self.cstats.affine_opportunities,
            "routed_spills": self.cstats.spills,
            "routed_cold": self.cstats.cold,
            "summary_rebuilds": self.cstats.summary_rebuilds,
            "compiles": max(rep["compiles"] for rep in reps),
            "per_replica": reps,
        }
