"""Deterministic workload-trace generator for the serving benchmarks.

Real serving load is not a uniform stream of unrelated prompts: arrival
processes are bursty or diurnal with heavy-tailed think times, and the
prompts themselves carry shared-prefix structure the paged engine's
prefix cache exists to exploit.  This module generates such traces
*reproducibly* — every trace is a pure function of its ``WorkloadSpec``
(family, arrival process, sizes, seed), drawn from a single
``np.random.default_rng(seed)``, so the same spec yields byte-identical
prompts, priorities, and arrival ticks on every machine.  That makes the
traces usable as audit evidence: ``compare_engines`` gets its
token-identity verdict over them, and the SLO benchmark judges p99
latency counters against expectations that only hold if the trace is
the same one it was calibrated on.

Families (the shared-prefix shapes):

- ``chat``  — multi-tenant chat: each tenant has a fixed system prompt
  (the shared prefix); requests cycle over tenants with a fresh user
  suffix.  Prefix reuse is per-tenant — the cache must keep several
  warm chains alive at once.
- ``rag``   — retrieval-augmented generation: one giant common context
  shared by *every* request plus a short per-request query.  The
  best-case for prefix caching — one chain, hit on every admit.
- ``agent`` — tool-use loops: each agent re-submits its entire previous
  prompt plus a few new tokens every turn, so prompts grow and each
  turn's prefix is exactly the previous turn's prompt.  Requests are
  ordered round-robin over agents by turn so arrival order never asks
  for turn k before turn k-1.

Arrival processes (units: engine ticks, nondecreasing):

- ``uniform``    — fixed ``mean_gap`` spacing (the legacy shape).
- ``bursty``     — clusters of ``burst_size`` near-simultaneous arrivals
  separated by ``burst_gap`` quiet ticks: the overload shape that makes
  preemption matter.
- ``diurnal``    — exponential gaps whose rate is modulated by a
  sinusoid (period/amplitude): slow troughs, dense peaks.
- ``heavy-tail`` — Pareto(α) gaps: most requests arrive promptly, a few
  after very long gaps (keeps the engine draining between spurts).

``WorkloadTrace.requests()`` returns *fresh* ``Request`` objects each
call (engines mutate requests in place), shaped exactly as
``Engine.submit`` expects — so a trace drops into ``compare_engines``
and ``run_requests`` unchanged.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.serve.api import SamplingParams
from repro.serve.engine import Request

FAMILIES = ("chat", "rag", "agent")
ARRIVALS = ("uniform", "bursty", "diurnal", "heavy-tail")


@dataclass(frozen=True)
class WorkloadSpec:
    """Everything that determines a trace.  Frozen: a spec is a cache
    key — two equal specs generate identical traces."""

    name: str
    family: str = "chat"           # chat | rag | agent
    arrival: str = "uniform"       # uniform | bursty | diurnal | heavy-tail
    n_requests: int = 16
    vocab_size: int = 50
    seed: int = 0
    max_new: int = 8
    # ---- shared-prefix structure
    prefix_len: int = 16           # system prompt / RAG context / agent base
    n_streams: int = 4             # tenants (chat) or agents (agent)
    suffix_lo: int = 2             # per-request fresh suffix length bounds
    suffix_hi: int = 8
    turns: int = 4                 # agent: re-submissions per agent
    grow: int = 4                  # agent: tokens appended per turn
    # ---- arrival-process knobs (engine ticks)
    mean_gap: float = 4.0
    burst_size: int = 4
    burst_gap: float = 32.0
    period: float = 64.0
    amplitude: float = 0.8
    pareto_alpha: float = 1.5
    # ---- request attributes
    priorities: tuple = (0,)       # cycled over requests in arrival order
    temperature: float = 0.0       # > 0 => counter-based sampled decoding
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"family must be one of {FAMILIES}, "
                             f"got {self.family!r}")
        if self.arrival not in ARRIVALS:
            raise ValueError(f"arrival must be one of {ARRIVALS}, "
                             f"got {self.arrival!r}")
        if self.n_requests < 1:
            raise ValueError("n_requests must be >= 1")
        if self.vocab_size < 2:
            raise ValueError("vocab_size must be >= 2")
        if not 1 <= self.suffix_lo <= self.suffix_hi:
            raise ValueError("need 1 <= suffix_lo <= suffix_hi")
        if self.prefix_len < 1 or self.n_streams < 1:
            raise ValueError("prefix_len and n_streams must be >= 1")
        if self.family == "agent" and (self.turns < 1 or self.grow < 1):
            raise ValueError("agent family needs turns >= 1 and grow >= 1")
        if not self.priorities:
            raise ValueError("priorities must be non-empty")

    # ------------------------------------------------------------- sizing
    @property
    def max_prompt_len(self) -> int:
        """Upper bound on any generated prompt length — the engine-sizing
        contract: ``max_len`` must cover ``max_prompt_len + max_new``."""
        if self.family == "agent":
            return self.prefix_len + self.turns * self.grow
        return self.prefix_len + self.suffix_hi

    @property
    def sampling(self) -> SamplingParams | None:
        if self.temperature <= 0:
            return None
        return SamplingParams(temperature=self.temperature,
                              top_k=self.top_k, top_p=self.top_p,
                              seed=self.seed % (2 ** 31))


@dataclass
class WorkloadTrace:
    """One generated trace: prompts / arrivals / priorities, index-aligned
    (index == rid).  ``requests()`` mints fresh Request objects so the
    trace can be replayed through any number of engines."""

    spec: WorkloadSpec
    prompts: list = field(default_factory=list)       # list[list[int]]
    arrivals: list = field(default_factory=list)      # nondecreasing ticks
    priorities: list = field(default_factory=list)

    @property
    def n_requests(self) -> int:
        return len(self.prompts)

    @property
    def max_feed(self) -> int:
        """Longest prompt + generation budget: the ``max_len`` floor."""
        return max(len(p) for p in self.prompts) + self.spec.max_new

    def requests(self) -> list:
        """Fresh ``Request`` objects (rid == trace index).  Engines mutate
        requests in place, so every replay needs its own copies."""
        sp = self.spec.sampling
        return [Request(rid=i, prompt=list(p), max_new=self.spec.max_new,
                        priority=self.priorities[i], sampling=sp)
                for i, p in enumerate(self.prompts)]

    # -------------------------------------------------------- diagnostics
    def shared_prefix_stats(self) -> dict:
        """How much prefix structure the trace actually carries.
        ``reuse_frac`` is the fraction of prompt tokens covered by the
        longest earlier-prompt common prefix — an upper bound on what a
        perfect prefix cache could skip (ignoring eviction and paging
        granularity)."""
        total = reusable = 0
        for i, p in enumerate(self.prompts):
            total += len(p)
            best = 0
            for q in self.prompts[:i]:
                n = 0
                for a, b in zip(p, q):
                    if a != b:
                        break
                    n += 1
                best = max(best, n)
            reusable += best
        return {
            "prompt_tokens": total,
            "reusable_tokens": reusable,
            "reuse_frac": round(reusable / total, 3) if total else 0.0,
        }

    def describe(self) -> dict:
        """Deterministic trace fingerprint for bench reports."""
        s = self.spec
        return {
            "workload": s.name, "family": s.family, "arrival": s.arrival,
            "n_requests": self.n_requests, "seed": s.seed,
            "max_prompt_len": max(len(p) for p in self.prompts),
            "max_feed": self.max_feed,
            "span_ticks": round(self.arrivals[-1], 2) if self.arrivals else 0,
            **self.shared_prefix_stats(),
        }


# ============================================================== arrivals


def _gaps(spec: WorkloadSpec, n: int, rng: np.random.Generator) -> list:
    """Inter-arrival gaps (ticks) for ``n`` requests, first gap included
    (request 0 need not arrive at t=0 for non-uniform processes)."""
    if spec.arrival == "uniform":
        return [spec.mean_gap] * n
    if spec.arrival == "bursty":
        gaps = []
        for i in range(n):
            at_burst_head = i % spec.burst_size == 0
            # head of each burst waits out the quiet period; members
            # inside a burst land almost together (jitter < 1 tick keeps
            # intra-burst submission order meaningful but adversarial)
            gaps.append(spec.burst_gap if at_burst_head and i
                        else float(rng.uniform(0.0, 0.5)))
        return gaps
    if spec.arrival == "diurnal":
        gaps, t = [], 0.0
        for _ in range(n):
            rate = (1.0 + spec.amplitude
                    * math.sin(2.0 * math.pi * t / spec.period))
            g = float(rng.exponential(spec.mean_gap)) / max(rate, 0.1)
            gaps.append(g)
            t += g
        return gaps
    # heavy-tail: Pareto(α) scaled so the mean gap matches mean_gap when
    # α > 1 (the α <= 1 regime has no mean; fall back to raw scale)
    a = spec.pareto_alpha
    scale = spec.mean_gap * (a - 1.0) / a if a > 1.0 else spec.mean_gap
    return [scale * float(1.0 + rng.pareto(a)) for _ in range(n)]


def _arrival_ticks(spec: WorkloadSpec, n: int,
                   rng: np.random.Generator) -> list:
    ticks, t = [], 0.0
    for g in _gaps(spec, n, rng):
        t += g
        ticks.append(round(t, 4))
    return ticks


# =============================================================== prompts


def _tokens(rng: np.random.Generator, n: int, vocab: int) -> list:
    # token 0 is reserved as padding in parts of the stack; draw from
    # [1, vocab) so prompts never alias the pad id
    return [int(x) for x in rng.integers(1, vocab, size=n)]


def _chat_prompts(spec: WorkloadSpec, rng: np.random.Generator) -> list:
    systems = [_tokens(rng, spec.prefix_len, spec.vocab_size)
               for _ in range(spec.n_streams)]
    prompts = []
    for i in range(spec.n_requests):
        suffix = _tokens(rng, int(rng.integers(spec.suffix_lo,
                                               spec.suffix_hi + 1)),
                         spec.vocab_size)
        prompts.append(systems[i % spec.n_streams] + suffix)
    return prompts


def _rag_prompts(spec: WorkloadSpec, rng: np.random.Generator) -> list:
    context = _tokens(rng, spec.prefix_len, spec.vocab_size)
    prompts = []
    for _ in range(spec.n_requests):
        query = _tokens(rng, int(rng.integers(spec.suffix_lo,
                                              spec.suffix_hi + 1)),
                        spec.vocab_size)
        prompts.append(context + query)
    return prompts


def _agent_prompts(spec: WorkloadSpec, rng: np.random.Generator) -> list:
    """Growing-prefix loops, round-robin over agents by turn: the output
    order is (agent0 turn0, agent1 turn0, ..., agent0 turn1, ...) so
    nondecreasing arrival ticks never schedule turn k before its own
    turn k-1 (whose prompt it extends)."""
    histories = [_tokens(rng, spec.prefix_len, spec.vocab_size)
                 for _ in range(spec.n_streams)]
    by_turn: list[list[list[int]]] = []
    for _ in range(spec.turns):
        this_turn = []
        for a in range(spec.n_streams):
            this_turn.append(list(histories[a]))
            histories[a] = histories[a] + _tokens(rng, spec.grow,
                                                  spec.vocab_size)
        by_turn.append(this_turn)
    flat = [p for turn in by_turn for p in turn]
    return flat[:spec.n_requests]


_FAMILY_BUILDERS = {
    "chat": _chat_prompts,
    "rag": _rag_prompts,
    "agent": _agent_prompts,
}


# ============================================================== generate


def generate(spec: WorkloadSpec) -> WorkloadTrace:
    """Build the trace for ``spec``.  Pure: one rng seeded from
    ``spec.seed`` drives prompts first, then arrivals — so adding new
    arrival processes never perturbs existing families' prompts."""
    rng = np.random.default_rng(spec.seed)
    prompts = _FAMILY_BUILDERS[spec.family](spec, rng)
    arrivals = _arrival_ticks(spec, len(prompts), rng)
    pr = spec.priorities
    priorities = [pr[i % len(pr)] for i in range(len(prompts))]
    return WorkloadTrace(spec=spec, prompts=prompts, arrivals=arrivals,
                         priorities=priorities)


# ===================================================== canonical suites


def smoke_specs(*, vocab_size: int = 50, seed: int = 0
                ) -> tuple[WorkloadSpec, ...]:
    """The benchmark suite's canonical small traces — one per family,
    each with a different arrival process so the matrix covers both
    axes.  Sized to fit the smoke engine (max_len 64: every spec's
    ``max_prompt_len + max_new`` stays under it)."""
    return (
        WorkloadSpec(name="chat-diurnal", family="chat", arrival="diurnal",
                     n_requests=12, vocab_size=vocab_size, seed=seed,
                     max_new=6, prefix_len=16, n_streams=3,
                     suffix_lo=2, suffix_hi=6, mean_gap=2.0,
                     priorities=(0, 1)),
        WorkloadSpec(name="rag-heavy-tail", family="rag",
                     arrival="heavy-tail", n_requests=10,
                     vocab_size=vocab_size, seed=seed + 1, max_new=6,
                     prefix_len=32, suffix_lo=2, suffix_hi=6,
                     mean_gap=3.0, pareto_alpha=1.6),
        WorkloadSpec(name="agent-bursty", family="agent", arrival="bursty",
                     n_requests=12, vocab_size=vocab_size, seed=seed + 2,
                     max_new=6, prefix_len=12, n_streams=3, turns=4,
                     grow=4, burst_size=3, burst_gap=24.0,
                     priorities=(0, 0, 1)),
    )
