"""Serving engine: slot-based KV cache + continuous batching.

The decode step is a fixed-shape jitted function over B slots; requests
stream in, occupy a free slot (their prompt prefilled into the slot's cache
rows), decode greedily until EOS/max_tokens, and release the slot.  This is
the vLLM-style continuous-batching control loop expressed over the
framework's fixed-shape ``decode_step`` — slot state lives in the engine,
tensor state in the donated cache.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    eos_id: int = -1            # -1: never stops early
    out: list[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclass
class EngineStats:
    served: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    batch_occupancy: list[int] = field(default_factory=list)

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.batch_occupancy)) if self.batch_occupancy else 0.0


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, slots: int = 4,
                 max_len: int = 256):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        cache = model.zero_cache(slots, max_len)
        self.cache = cache
        self.pos = np.zeros((slots,), np.int32)       # next write position
        self.active: dict[int, Request] = {}          # slot -> request
        self.stats = EngineStats()
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._last_token = np.zeros((slots, 1), np.int32)

    # ------------------------------------------------------------ admit
    def _free_slots(self) -> list[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def _admit(self, req: Request, slot: int) -> None:
        """Prefill the prompt into this slot serially (single-slot prefill;
        a production engine would batch same-length prompts)."""
        req.t_submit = req.t_submit or time.perf_counter()
        tokens = req.prompt[-(self.max_len - req.max_new):]
        # step the prompt through decode one token at a time into the slot
        # rows (slot-local prefill keeps the cache layout identical)
        for i, tok in enumerate(tokens):
            self._last_token[slot, 0] = tok
            self.pos[slot] = i
            logits, self.cache = self._decode(
                self.params, self.cache,
                jnp.asarray(self._last_token), jnp.asarray(self.pos))
        self.pos[slot] = len(tokens)
        nxt = int(jnp.argmax(logits[slot]))
        req.out.append(nxt)
        req.t_first = time.perf_counter()
        self._last_token[slot, 0] = nxt
        self.active[slot] = req

    # ------------------------------------------------------------- run
    def run(self, requests: list[Request]) -> list[Request]:
        pending = list(requests)
        done: list[Request] = []
        while pending or self.active:
            while pending and self._free_slots():
                self._admit(pending.pop(0), self._free_slots()[0])

            if not self.active:
                continue
            logits, self.cache = self._decode(
                self.params, self.cache,
                jnp.asarray(self._last_token), jnp.asarray(self.pos))
            self.stats.decode_steps += 1
            self.stats.batch_occupancy.append(len(self.active))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))

            finished = []
            for slot, req in self.active.items():
                tok = int(nxt[slot])
                req.out.append(tok)
                self.stats.tokens_out += 1
                self.pos[slot] += 1
                self._last_token[slot, 0] = tok
                if (tok == req.eos_id or len(req.out) >= req.max_new
                        or self.pos[slot] >= self.max_len - 1):
                    req.t_done = time.perf_counter()
                    finished.append(slot)
            for slot in finished:
                done.append(self.active.pop(slot))
                self.stats.served += 1
        return done
