"""Serving engines: contiguous slot caches (oracle) and the paged path.

Both engines implement the ``serve.api.Engine`` protocol — ``submit /
step / drain / cancel / report`` — so every caller (launchers, examples,
benchmarks, the audit pipeline) speaks one request-lifecycle contract and
the seed's two incompatible ``run()`` shapes survive only as the
``api.run_requests`` compatibility shim.

``ServeEngine`` is the seed contiguous engine: a fixed-shape jitted
decode step over B slots, serial per-token prefill at admission.  It is
kept as the *dual-environment oracle* — the paged engine's correctness
proof is a ``compare_engines`` verdict (core.verify.DualEnvHarness) that
the two produce identical token streams, greedy AND sampled (counter-
based per-request PRNG keys make sampled streams engine-independent).

``PagedServeEngine`` is the production path: a refcounted block allocator
+ hash-chained prefix cache (serve.paging) so overlapping prompts reuse KV
pages instead of recomputing them, chunked prefill so a long prompt
consumes C tokens per step in the same batched call that advances
decoding lanes by one, and a priority scheduler (serve.scheduler) with
preemption-on-OOM.  Preempted work parks its written KV pages on a host
swap tier (serve.paging.HostSwapPool) and readmission swaps them back in
— recompute-on-readmit survives as the costed fallback (and the audited
``swap=False`` misconfiguration).  Its default KV pathway
(``kernel="paged"``) keeps the cache *in the page pool on device* and
attends it through the per-slot page table (``decode_paged_chunk`` →
``kernels.paged_attention``); the dense per-slot working cache survives
only as the audited ``kernel="gather"`` fallback.

Sampling is fused into the jitted step (``models.decode.
sample_from_logits``): the engines exchange only ``[B]`` token vectors
with the device, and per-lane sampling state rides fixed-shape arrays —
no shape polymorphism, no recompiles, no host-side logits traffic.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.audit.trace import NULL_TRACER, Tracer
from repro.models.decode import CompileWatcher
from repro.models.model import Model
from repro.serve.api import (GREEDY, LaneState, RequestHandle, SamplingParams,
                             run_requests)
from repro.serve.paging import (BlockAllocator, DevicePageView, HostSwapPool,
                                KVPool, PrefixCache, chain_hashes, pages_for)
from repro.serve.scheduler import (DONE, PREEMPTED, RUNNING, WAITING, Plan,
                                   SchedEntry, Scheduler, SwapCostModel)

# quantile feeds (ttft_ticks) keep at most this many samples: a bounded
# ring, not an unbounded per-request append, so a long-lived serving
# process holds steady-state memory
LATENCY_RING = 4096


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    eos_id: int = -1            # -1: never stops early
    priority: int = 0           # higher preempts lower on OOM (paged path)
    sampling: SamplingParams | None = None   # None => greedy
    out: list[int] = field(default_factory=list)
    finished: bool = False
    cancelled: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


def _validate(req: Request) -> None:
    """Static request validation shared by both engines' ``submit``."""
    if not req.prompt:
        raise ValueError(f"request {req.rid}: empty prompt (decoding "
                         f"needs at least one token of context)")
    if not -2**31 <= req.rid < 2**31:
        # the rid rides an int32 lane array into the jitted step
        raise ValueError(f"request id {req.rid} does not fit int32")


def _validate_fit(req: Request, max_len: int) -> None:
    """Reject a generation budget the slot geometry cannot hold.  Both
    engines clamp the prompt to ``prompt[-(max_len - max_new):]``; with
    ``max_new >= max_len`` that slice silently degenerates (``[-0:]``
    keeps the whole prompt, larger budgets truncate the wrong end) and
    the request only dies later, deep in page-table binding."""
    if req.max_new < 1:
        raise ValueError(
            f"request {req.rid}: max_new={req.max_new} must be >= 1")
    if req.max_new >= max_len:
        raise ValueError(
            f"request {req.rid}: max_new={req.max_new} must be < "
            f"max_len={max_len} (the prompt is clamped to max_len - "
            f"max_new tokens of context; no context would remain)")


def _samples(req: Request) -> bool:
    return not (req.sampling or GREEDY).greedy


# Fixed-shape page movers for the swap tier.  The page/slot index is a
# *traced* argument, so each helper compiles exactly once per pool shape;
# eager ``.at[idx].set`` would bake the index (and the page count) into
# the program and pay a fresh XLA compile on nearly every swap.
@jax.jit
def _read_page(pool, bid):
    """One page ``(layers, block_size, kv, hd)`` out of the device pool."""
    return jax.lax.dynamic_slice_in_dim(pool, bid, 1, axis=1)[:, 0]


@jax.jit
def _write_page(pool, bid, page):
    return jax.lax.dynamic_update_slice_in_dim(pool, page[:, None], bid,
                                               axis=1)


@jax.jit
def _read_slot(cache, slot):
    """One slot's dense rows ``(layers, max_len, kv, hd)`` (gather mode)."""
    return jax.lax.dynamic_slice_in_dim(cache, slot, 1, axis=1)[:, 0]


@jax.jit
def _write_slot(cache, slot, slab):
    return jax.lax.dynamic_update_slice_in_dim(cache, slab[:, None], slot,
                                               axis=1)


@dataclass
class EngineStats:
    served: int = 0
    cancelled: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    # bounded occupancy accumulator (running sum + tick count) instead of
    # an unbounded per-tick list: the mean is exact (integer sum / count,
    # same value np.mean produced) and memory is O(1) for long-lived
    # serving processes
    occupancy_sum: int = 0
    occupancy_ticks: int = 0

    def observe_occupancy(self, lanes: int) -> None:
        self.occupancy_sum += lanes
        self.occupancy_ticks += 1

    @property
    def mean_occupancy(self) -> float:
        return (self.occupancy_sum / self.occupancy_ticks
                if self.occupancy_ticks else 0.0)


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, slots: int = 4,
                 max_len: int = 256, tracer: Tracer | None = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        cache = model.zero_cache(slots, max_len)
        self.cache = cache
        self.pos = np.zeros((slots,), np.int32)       # next write position
        self.active: dict[int, Request] = {}          # slot -> request
        self.pending: list[tuple[float, Request]] = []  # (arrival, req) FCFS
        self.now = 0.0                                # step-counter clock
        self.lane = LaneState(slots)
        self.stats = EngineStats()
        self.trace = tracer or NULL_TRACER
        # two fused programs, dispatched per call on whether any lane in
        # the batch actually samples: all-greedy serving (the default)
        # never lowers the sampling pipeline and pays exactly the seed
        # engine's cost; jax.jit is lazy, so the unused variant never
        # compiles.  Distinct watcher names keep the per-program
        # compile expectation (max 1) meaningful for both.
        self._decode = CompileWatcher(
            jax.jit(model.decode_greedy_step, donate_argnums=(1,)),
            "decode_step", on_compile=self._on_compile)
        self._decode_sample = CompileWatcher(
            jax.jit(model.decode_sample_step, donate_argnums=(1,)),
            "decode_sample_step", on_compile=self._on_compile)
        self._last_token = np.zeros((slots, 1), np.int32)
        self.trace.emit("engine-init", engine="contiguous",
                        family=model.cfg.family, arch=model.cfg.name,
                        slots=slots, max_len=max_len)

    def _on_compile(self, fn: str, reason: str, sig: tuple) -> None:
        self.trace.emit("compile", fn=fn, reason=reason, signature=sig)

    # ------------------------------------------------------------ intake
    def submit(self, req: Request, *, arrival: float | None = None
               ) -> RequestHandle:
        _validate(req)
        _validate_fit(req, self.max_len)
        arrival = self.now if arrival is None else arrival
        req.t_submit = req.t_submit or time.perf_counter()
        self.pending.append((arrival, req))
        self.trace.emit("submit", rid=req.rid, tick=self.now,
                        arrival=arrival, prompt_tokens=len(req.prompt),
                        max_new=req.max_new,
                        sampling=(req.sampling or GREEDY).describe())
        return RequestHandle(self, req)

    def has_work(self) -> bool:
        return bool(self.pending or self.active)

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def _admit(self, req: Request, slot: int, arrival: float) -> None:
        """Prefill the prompt into this slot serially (single-slot prefill;
        a production engine would batch same-length prompts)."""
        tokens = req.prompt[-(self.max_len - req.max_new):]
        self.lane.set(slot, req)     # step=0: the first output token's key
        # step the prompt through decode one token at a time into the slot
        # rows (slot-local prefill keeps the cache layout identical; other
        # lanes' rows are recomputed idempotently and their sampled tokens
        # discarded — counter-based keys consume no stream state).  Only
        # the final step's token is read, so only it needs the sampled
        # program; every earlier step takes the cheap argmax variant
        # (the cache updates are identical).
        for i, tok in enumerate(tokens):
            self._last_token[slot, 0] = tok
            self.pos[slot] = i
            if _samples(req) and i == len(tokens) - 1:
                toks, self.cache = self._decode_sample(
                    self.params, self.cache,
                    jnp.asarray(self._last_token), jnp.asarray(self.pos),
                    self.lane.as_args())
            else:
                toks, self.cache = self._decode(
                    self.params, self.cache,
                    jnp.asarray(self._last_token), jnp.asarray(self.pos))
        self.pos[slot] = len(tokens)
        nxt = int(np.asarray(toks)[slot])
        req.out.append(nxt)
        req.t_first = time.perf_counter()
        self._last_token[slot, 0] = nxt
        self.active[slot] = req
        # the serial prefill completes inside the admission tick, so the
        # admit / prefill-done / first-token boundaries coincide — the
        # timeline layer orders them by kind within the tick
        self.trace.emit("admit", rid=req.rid, slot=slot, tick=self.now,
                        prompt_tokens=len(tokens), cached_tokens=0)
        self.trace.emit("prefill-done", rid=req.rid, tick=self.now,
                        slot=slot, consumed=len(tokens))
        self.trace.emit("first-token", rid=req.rid, tick=self.now,
                        ttft_ticks=self.now - arrival)

    def _retire(self, slot: int) -> Request:
        req = self.active.pop(slot)
        req.finished = True
        req.t_done = time.perf_counter()
        self.lane.clear(slot)
        self.trace.emit("finish", rid=req.rid, slot=slot, tick=self.now,
                        tokens_out=len(req.out))
        self.stats.served += 1
        return req

    # -------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """One engine tick: admit ready pending requests (strict FCFS)
        into free slots, then one batched fused decode call (greedy or
        sampled program, chosen by the batch's request mix)."""
        self.now += 1.0
        done: list[Request] = []
        # FCFS over *ready* requests, matching the paged scheduler's
        # arrival semantics: a future-dated head must not block a ready
        # request behind it (the two Engine implementations agree on
        # out-of-order arrivals)
        i = 0
        while i < len(self.pending) and self._free_slots():
            arrival, req = self.pending[i]
            if arrival > self.now:
                i += 1
                continue
            self.pending.pop(i)
            slot = self._free_slots()[0]
            self._admit(req, slot, arrival)
            # the admission-produced first token can already satisfy the
            # finish conditions (max_new=1, eos on first token): retire
            # now, exactly like the paged engine does after prefill
            tok = req.out[-1]
            if (tok == req.eos_id or len(req.out) >= req.max_new
                    or self.pos[slot] >= self.max_len - 1):
                done.append(self._retire(slot))

        if not self.active:
            return done
        if any(_samples(r) for r in self.active.values()):
            for slot, req in self.active.items():
                self.lane.set(slot, req)
            toks, self.cache = self._decode_sample(
                self.params, self.cache,
                jnp.asarray(self._last_token), jnp.asarray(self.pos),
                self.lane.as_args())
        else:
            toks, self.cache = self._decode(
                self.params, self.cache,
                jnp.asarray(self._last_token), jnp.asarray(self.pos))
        self.stats.decode_steps += 1
        self.stats.observe_occupancy(len(self.active))
        self.trace.emit("step", step_kind="decode", lanes=len(self.active))
        nxt = np.asarray(toks)

        finished = []
        for slot, req in self.active.items():
            tok = int(nxt[slot])
            req.out.append(tok)
            self.stats.tokens_out += 1
            self.pos[slot] += 1
            self._last_token[slot, 0] = tok
            if (tok == req.eos_id or len(req.out) >= req.max_new
                    or self.pos[slot] >= self.max_len - 1):
                finished.append(slot)
        return done + [self._retire(slot) for slot in finished]

    def drain(self) -> list[Request]:
        done: list[Request] = []
        while self.has_work():
            done.extend(self.step())
        return done

    # ------------------------------------------------------------ cancel
    def cancel(self, handle: RequestHandle) -> bool:
        req = handle.req
        if req.finished or req.cancelled:
            return False
        phase = None
        for i, (_, r) in enumerate(self.pending):
            if r is req:
                self.pending.pop(i)
                phase = "waiting"
                break
        if phase is None:
            for slot, r in list(self.active.items()):
                if r is req:
                    self.active.pop(slot)
                    self.lane.clear(slot)
                    phase = "decode"     # contiguous has no mid-prefill gap
                    break
        if phase is None:
            return False
        req.cancelled = True
        req.t_done = time.perf_counter()
        self.stats.cancelled += 1
        self.trace.emit("cancel", rid=req.rid, phase=phase, tick=self.now,
                        released_pages=0)
        return True

    # ---------------------------------------------------------- run shim
    def run(self, requests: list[Request],
            arrivals: list[float] | None = None) -> list[Request]:
        return run_requests(self, requests, arrivals)

    # -------------------------------------------------------------- report
    def report(self) -> dict:
        return {
            "engine": "contiguous",
            "served": self.stats.served,
            "cancelled": self.stats.cancelled,
            "decode_steps": self.stats.decode_steps,
            "tokens_out": self.stats.tokens_out,
            "mean_batch_occupancy": round(self.stats.mean_occupancy, 2),
            # worst per-program count: each fused variant (greedy /
            # sampled) should compile at most once; a genuine hot-loop
            # recompile shows up as > 1 on a single watcher
            "compiles": max(self._decode.compiles,
                            self._decode_sample.compiles),
        }


# ================================================================== paged


def _chunk_fn_for(model: Model, sampled: bool, kernel: bool = False):
    """One jitted chunk step per (Model instance, variant), shared by
    every engine built on it (benchmark sweeps construct many engines;
    recompiling per engine would dominate wall time).  Cached on the
    model itself so its lifetime — and the compiled executables' — ends
    with the model.  Four variants on two axes: fused argmax for
    all-greedy batches (the sampling pipeline never lowers) vs fused
    sampling, and the paged-kernel step (KV through the page table) vs
    the dense-working-cache step; jax.jit is lazy, so unused variants
    never compile."""
    attr = (f"_{'paged' if kernel else 'chunk'}"
            f"_{'sample' if sampled else 'greedy'}_jit")
    fn = getattr(model, attr, None)
    if fn is None:
        target = {
            (False, False): model.decode_greedy_chunk,
            (False, True): model.decode_sample_chunk,
            (True, False): model.decode_paged_greedy_chunk,
            (True, True): model.decode_paged_sample_chunk,
        }[(kernel, sampled)]
        fn = jax.jit(target, donate_argnums=(1,))
        setattr(model, attr, fn)
    return fn


@dataclass
class PagedStats:
    prefill_tokens: int = 0      # prompt tokens actually computed
    cached_tokens: int = 0       # prompt tokens served from the prefix cache
    admit_retries: int = 0       # admissions bounced by an intra-tick race
    # host swap tier accounting: every readmission of previously-computed
    # rows either restores them from the tier (swap-in) or re-prefills
    # them (recompute) — the restore rate is the tiering pathway's health
    # signal the audit layer gates on
    restored_tokens: int = 0     # KV rows swapped back in on readmission
    recompute_tokens: int = 0    # previously-computed rows re-prefilled
    swap_outs: int = 0           # preemptions that parked pages on host
    swap_ins: int = 0            # readmissions served from the host tier

    @property
    def prefix_hit_rate(self) -> float:
        total = self.prefill_tokens + self.cached_tokens
        return self.cached_tokens / total if total else 0.0

    @property
    def swap_restore_rate(self) -> float:
        total = self.restored_tokens + self.recompute_tokens
        return self.restored_tokens / total if total else 0.0


@dataclass
class _SwapRecord:
    """A preempted request's host-parked state: the KV rows it had
    written, page-granular, plus how many rows they cover.  ``host_ids``
    is empty when the tier was full or swap is disabled — the record
    still rides along so recompute on readmission is attributed."""
    consumed: int
    host_ids: list[int] = field(default_factory=list)


@dataclass
class _Slot:
    entry: SchedEntry
    req: Request
    feed: list[int]              # prompt (clamped) + generated-so-far
    hashes: list[int]            # chain hashes over full blocks of feed
    pending: list[int]           # feed tokens not yet computed
    consumed: int                # KV rows written (= next write position)
    shared: list[int]            # matched prefix pages (refs held)
    private: list[int]           # pages allocated for this request
    registered: int              # full feed blocks registered / matched
    reg_cursor: int = 0          # next private page usable for registration
    next_input: int = -1         # decode-phase input token
    table: list[int] = field(default_factory=list)  # logical block -> page
                                 # (kernel mode: shared then private, in
                                 # feed order; block i's KV lives wholly
                                 # in physical page table[i])


class PagedServeEngine:
    """Paged-KV continuous batching: prefix reuse + chunked prefill.

    Every step is one fixed-shape chunked call: prefill lanes feed up to
    ``chunk`` prompt tokens, decode lanes feed their last sampled token,
    idle lanes feed nothing (n_new=0).

    ``kernel`` selects the KV pathway:

    - ``"paged"`` (default, the production path): KV lives in a shared
      device page pool (``serve.paging.DevicePageView``) and the jitted
      step (``decode_paged_*_chunk``) writes and attends *through the
      page table* via the Pallas paged-attention kernel.  Prefix hits
      are pure metadata — the matched pages simply appear in the new
      slot's table row, zero copies — and registration publishes the
      page a block already lives in.
    - ``"gather"`` (the audited fallback): the dense per-slot working
      cache remains the jitted working set and admissions gather
      registered prefix KV from a host ``KVPool`` into slot rows — the
      contiguous-shaped detour the audit layer flags as
      ``pathway-kernel`` on dense/moe serving.

    Deterministic by construction: the scheduler runs on the engine's
    synthetic tick clock, so a trace (prompts, priorities, arrivals)
    replays to the same schedule and the same token streams — greedy and
    sampled alike, because sampled tokens key on (seed, rid, step), not
    on slots or schedule.

    ``admit_every`` batches scheduler invocations to every N-th tick
    (N=1, the default, schedules every tick).  Values > 1 model a
    misconfigured admission interval: output streams are unchanged but
    TTFT inflates — the audit's per-request latency expectations exist to
    catch exactly this class.
    """

    def __init__(self, model: Model, params: Any, *, slots: int = 4,
                 max_len: int = 256, block_size: int = 16,
                 num_blocks: int | None = None, chunk: int = 8,
                 tick_dt: float = 1.0, use_prefix_cache: bool = True,
                 admit_every: int = 1, kernel: str = "paged",
                 preemption: bool = True, swap: bool = True,
                 host_blocks: int | None = None,
                 swap_cost: SwapCostModel | None = None,
                 tracer: Tracer | None = None):
        if model.cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"paged engine needs an attention cache (dense/moe); "
                f"{model.cfg.family!r} serves through ServeEngine")
        if admit_every < 1:
            raise ValueError(f"admit_every must be >= 1, got {admit_every}")
        if kernel not in ("paged", "gather"):
            raise ValueError(
                f"kernel must be 'paged' (attend through the page table) "
                f"or 'gather' (dense working-cache fallback), got {kernel!r}")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.chunk = chunk
        self.kernel = kernel
        if num_blocks is None:
            num_blocks = 2 * slots * pages_for(max_len, block_size)
        self.alloc = BlockAllocator(num_blocks, block_size)
        self.prefix = PrefixCache(self.alloc)
        self.prefix_enabled = use_prefix_cache
        # host swap tier: preempted requests park written pages here and
        # readmission swaps them back in instead of re-prefilling; cold
        # prefix pages evicted under pressure spill to the same tier.
        # swap=False models the misconfigured deployment (device-only
        # residency, always-recompute) the tiering audit exists to catch.
        self.swap_enabled = swap
        if host_blocks is None:
            host_blocks = 2 * num_blocks
        self.host = HostSwapPool(host_blocks, block_size)
        self.swap_cost = swap_cost or SwapCostModel()
        self._swap_records: dict[int, _SwapRecord] = {}   # entry.seq -> rec
        if kernel == "paged":
            # KV storage IS the device page pool; no host KVPool, no
            # per-slot working cache, no admission gather.  Geometry
            # comes from the declarative spec the jitted paged step is
            # written against, so the two cannot drift.
            spec = model.paged_cache_specs(num_blocks, block_size)
            layers, _, _, n_kv, hd = spec["paged"]["k"].shape
            self.pool = None
            self.view = DevicePageView(
                num_blocks, block_size, layers, n_kv, hd,
                spec["paged"]["k"].dtype,
                slots=slots, max_pages=pages_for(max_len, block_size))
            self.cache = self.view.cache()
        else:
            # dense-cache geometry without materializing it twice
            k = model.abstract_cache(slots, max_len)["self"]["k"]
            layers, _, _, n_kv, hd = k.shape
            self.pool = KVPool(num_blocks, block_size, layers, n_kv, hd,
                               k.dtype)
            self.view = None
            self.cache = model.zero_cache(slots, max_len)
        if swap and use_prefix_cache and kernel == "paged":
            # cold-prefix spill rides the same host tier (kernel mode
            # only: gather-mode registered pages already live in the host
            # KVPool, spilling them would copy host to host)
            self.prefix.attach_spill(
                spill_out=self._spill_page, page_in=self._page_in,
                drop=self.host.decref, capacity=host_blocks)
        self.now = 0.0
        self.tick_dt = tick_dt
        self.admit_every = admit_every
        self._ticks = 0
        self.lane = LaneState(slots)
        # engine events carry ``tick`` (the synthetic clock) in their
        # payload rather than rebinding the caller-owned tracer's clock:
        # replayed traces (same prompts, priorities, arrivals) still
        # produce identical (kind, data) streams, and a tracer shared
        # with other emitters keeps its own timestamps
        self.trace = tracer or NULL_TRACER
        self.sched = Scheduler(slots=slots, clock=lambda: self.now,
                               tracer=self.trace, preemption=preemption)
        self.active: dict[int, _Slot] = {}
        self.stats = EngineStats()
        self.pstats = PagedStats()
        # first-token latency, tick clock — bounded ring (quantile feed)
        self.ttft_ticks: deque[float] = deque(maxlen=LATENCY_RING)
        def _on_compile(fn, reason, sig):
            self.trace.emit("compile", fn=fn, reason=reason, signature=sig)

        paged = kernel == "paged"
        self._chunk_fn = CompileWatcher(
            _chunk_fn_for(model, sampled=False, kernel=paged),
            "decode_paged_chunk" if paged else "decode_chunk",
            on_compile=_on_compile)
        self._chunk_sample_fn = CompileWatcher(
            _chunk_fn_for(model, sampled=True, kernel=paged),
            "decode_paged_sample_chunk" if paged else "decode_sample_chunk",
            on_compile=_on_compile)
        self.trace.emit("engine-init", engine="paged",
                        family=model.cfg.family, arch=model.cfg.name,
                        slots=slots, max_len=max_len, block_size=block_size,
                        chunk=chunk, pages=num_blocks,
                        prefix_cache=use_prefix_cache,
                        admit_every=admit_every, kernel=kernel,
                        preemption=preemption, swap=swap,
                        host_pages=host_blocks)

    # ------------------------------------------------------------ intake
    def submit(self, req: Request, *, arrival: float | None = None
               ) -> RequestHandle:
        # reject statically-unplaceable requests here, where only the bad
        # request fails — once queued, it would starve everything behind
        # it (strict head-of-line) without ever becoming admissible
        _validate(req)
        _validate_fit(req, self.max_len)
        worst = pages_for(len(self._feed_of(req)) + req.max_new,
                          self.alloc.block_size)
        if worst > self.alloc.num_blocks:
            raise ValueError(
                f"request {req.rid} needs {worst} pages even fully "
                f"recomputed; pool has {self.alloc.num_blocks}")
        arrival = self.now if arrival is None else arrival
        req.t_submit = req.t_submit or time.perf_counter()
        entry = self.sched.submit(req, priority=req.priority, arrival=arrival)
        self.trace.emit("submit", rid=req.rid, tick=self.now,
                        arrival=arrival, prompt_tokens=len(req.prompt),
                        max_new=req.max_new,
                        sampling=(req.sampling or GREEDY).describe())
        return RequestHandle(self, req, entry)

    def has_work(self) -> bool:
        return self.sched.has_work()

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def _feed_of(self, req: Request) -> list[int]:
        prompt = req.prompt[-(self.max_len - req.max_new):]
        return list(prompt) + list(req.out)

    def _cost(self, entry: SchedEntry) -> int:
        """Net new pages if admitted now (prefix hits are shared, free).

        A preempted entry whose readmission the ``SwapCostModel`` prices
        cheaper as a swap-in costs its *full* page count — every page
        comes back as a private page, no prefix sharing — which keeps the
        scheduler's feasibility arithmetic exact for both pathways."""
        req = entry.req
        feed = self._feed_of(req)
        total = pages_for(len(feed) + req.max_new - len(req.out),
                          self.alloc.block_size)
        if self._restorable(entry) is not None:
            return total
        matched = (self.prefix.peek(feed, max_tokens=len(feed) - 1)
                   if self.prefix_enabled else 0)
        return total - matched // self.alloc.block_size

    # --------------------------------------------------------- host tier
    def _restorable(self, entry: SchedEntry) -> _SwapRecord | None:
        """The entry's swap record, iff restoring it beats recomputing."""
        rec = self._swap_records.get(entry.seq)
        if (rec is not None and rec.host_ids
                and self.swap_cost.prefer_swap(len(rec.host_ids),
                                               rec.consumed)):
            return rec
        return None

    def _spill_page(self, bid: int) -> int | None:
        """PrefixCache spill hook: copy one device page's rows to the
        host tier (kernel mode; returns None when the tier is full)."""
        hid = self.host.put(np.asarray(_read_page(self.view.k, bid)),
                            np.asarray(_read_page(self.view.v, bid)))
        if hid is not None:
            self.trace.emit("swap-out", rid=None, tick=self.now,
                            reason="prefix-spill", pages=1,
                            tokens=self.alloc.block_size,
                            pages_in_use=self.alloc.in_use,
                            host_pages_in_use=self.host.in_use)
        return hid

    def _page_in(self, hid: int) -> int | None:
        """PrefixCache restore hook: allocate a device page and copy a
        spilled page's rows back (None when the device pool is empty —
        the match stops at the resident prefix)."""
        if self.alloc.num_free == 0:
            return None
        bid = self.alloc.alloc()
        k_rows, v_rows = self.host.get(hid)
        self.view.k = _write_page(self.view.k, bid, jnp.asarray(k_rows))
        self.view.v = _write_page(self.view.v, bid, jnp.asarray(v_rows))
        self.cache = self.view.cache()   # rebind: the writes made new arrays
        self.trace.emit("swap-in", rid=None, tick=self.now,
                        reason="prefix-restore", pages=1,
                        tokens=self.alloc.block_size,
                        pages_in_use=self.alloc.in_use,
                        host_pages_in_use=self.host.in_use)
        return bid

    def _drop_swap(self, entry: SchedEntry, *, swapped_in: bool = False
                   ) -> _SwapRecord | None:
        """Release an entry's host-parked pages (readmit or cancel)."""
        rec = self._swap_records.pop(entry.seq, None)
        if rec is not None:
            for hid in rec.host_ids:
                self.host.decref(hid, swapped_in=swapped_in)
        return rec

    # ------------------------------------------------------------- admit
    def _admit(self, entry: SchedEntry,
               victims: tuple[SchedEntry, ...] = ()) -> bool:
        """Place one candidate, preempting its planned ``victims`` only
        once admission is guaranteed.  The budget check happens *after*
        the prefix match — matched pages the plan counted as evictable
        are pinned by the match's references, so measuring free +
        evictable at that point (plus the pages each victim will release)
        is exact: a candidate that fails here fails before any running
        work is flushed."""
        req: Request = entry.req
        bs = self.alloc.block_size
        feed = self._feed_of(req)
        total = pages_for(len(feed) + req.max_new - len(req.out), bs)
        rec = self._restorable(entry)
        if rec is not None:
            return self._admit_restore(entry, feed, total, rec, victims)
        # leave ≥1 token to feed so the last-position logits exist
        if self.prefix_enabled:
            matched_len, shared = self.prefix.match(feed,
                                                    max_tokens=len(feed) - 1)
        else:
            matched_len, shared = 0, []
        need = total - len(shared)
        budget = (self.alloc.num_free + self.prefix.evictable()
                  + sum(v.held_pages for v in victims))
        if need > budget:
            for bid in shared:      # lost an intra-tick race; stay waiting
                self.alloc.decref(bid)
            self.pstats.admit_retries += 1
            return False
        for v in victims:           # guaranteed to buy the admission now
            self._preempt(v)
        if need > self.alloc.num_free:
            self.prefix.evict(need - self.alloc.num_free)
        if need > self.alloc.num_free:  # pragma: no cover - budget-guarded
            for bid in shared:
                self.alloc.decref(bid)
            self.pstats.admit_retries += 1
            return False
        private = [self.alloc.alloc() for _ in range(need)]
        slot = self._free_slots()[0]

        if self.kernel == "paged":
            # zero-copy prefix reuse: the matched pages (and the fresh
            # private ones) become this slot's page-table row; the kernel
            # attends the shared pages in place
            table = shared + private
            self.view.bind_slot(slot, table)
            self.pstats.cached_tokens += matched_len
        else:
            table = []
            if matched_len:         # prefix hit: pages -> slot rows, no math
                kp, vp = self.pool.read(shared)
                kc, vc = self.cache["self"]["k"], self.cache["self"]["v"]
                self.cache["self"]["k"] = kc.at[:, slot, :matched_len].set(
                    jnp.asarray(kp[:, :matched_len]))
                self.cache["self"]["v"] = vc.at[:, slot, :matched_len].set(
                    jnp.asarray(vp[:, :matched_len]))
                self.pstats.cached_tokens += matched_len

        self.active[slot] = _Slot(
            entry=entry, req=req, feed=feed,
            hashes=chain_hashes(feed, bs),
            pending=feed[matched_len:], consumed=matched_len,
            shared=shared, private=private, registered=matched_len // bs,
            table=table)
        self.sched.mark_running(entry, slot, len(private))
        dropped = self._drop_swap(entry)
        if dropped is not None:
            # a readmission the cost model (or a full/disabled tier) sent
            # down the recompute path: previously-computed rows beyond the
            # prefix hit are re-prefilled
            self.pstats.recompute_tokens += max(0,
                                                dropped.consumed - matched_len)
        # pages_in_use rides every occupancy-changing event so the live
        # metrics layer can histogram pool pressure straight off the
        # trace (deterministic: the allocator count is schedule state)
        self.trace.emit("admit", rid=req.rid, slot=slot, tick=self.now,
                        feed_tokens=len(feed), cached_tokens=matched_len,
                        new_pages=len(private), shared_pages=len(shared),
                        pages_in_use=self.alloc.in_use)
        return True

    def _admit_restore(self, entry: SchedEntry, feed: list[int],
                       total: int, rec: _SwapRecord,
                       victims: tuple[SchedEntry, ...] = ()) -> bool:
        """Swap-in readmission: every page comes back as a private page
        (no prefix match — the host copy is already exact), the parked
        rows are copied into the fresh pages, and the slot resumes at the
        preempted position.  Token-exact with the recompute pathway: the
        restored rows ARE the rows an uninterrupted run had written, and
        ``pending = feed[consumed:]`` resumes the same chunk arithmetic."""
        req: Request = entry.req
        bs = self.alloc.block_size
        need = total
        budget = (self.alloc.num_free + self.prefix.evictable()
                  + sum(v.held_pages for v in victims))
        if need > budget:
            # intra-tick race: stay waiting, the record stays parked
            self.pstats.admit_retries += 1
            return False
        for v in victims:
            self._preempt(v)
        if need > self.alloc.num_free:
            self.prefix.evict(need - self.alloc.num_free)
        if need > self.alloc.num_free:  # pragma: no cover - budget-guarded
            self.pstats.admit_retries += 1
            return False
        private = [self.alloc.alloc() for _ in range(need)]
        slot = self._free_slots()[0]
        n_pages = len(rec.host_ids)
        if self.kernel == "paged":
            k, v = self.view.k, self.view.v
            for bid, hid in zip(private, rec.host_ids):
                k_rows, v_rows = self.host.get(hid)
                k = _write_page(k, bid, jnp.asarray(k_rows))
                v = _write_page(v, bid, jnp.asarray(v_rows))
            self.view.k, self.view.v = k, v
            self.cache = self.view.cache()   # rebind the fresh arrays
            table = list(private)
            self.view.bind_slot(slot, table)
        else:
            table = []
            rows = min(n_pages * bs, self.max_len)
            kc, vc = self.cache["self"]["k"], self.cache["self"]["v"]
            # full-slab write keeps the shape fixed; rows >= consumed are
            # never read before the decode loop rewrites them, so zeros
            # beyond the restored rows are as good as the stale occupant
            k_slab = np.zeros((kc.shape[0],) + tuple(kc.shape[2:]),
                              dtype=kc.dtype)
            v_slab = np.zeros_like(k_slab)
            k_slab[:, :rows] = np.concatenate(
                [self.host.get(h)[0] for h in rec.host_ids],
                axis=1)[:, :rows]
            v_slab[:, :rows] = np.concatenate(
                [self.host.get(h)[1] for h in rec.host_ids],
                axis=1)[:, :rows]
            self.cache["self"]["k"] = _write_slot(kc, slot,
                                                  jnp.asarray(k_slab))
            self.cache["self"]["v"] = _write_slot(vc, slot,
                                                  jnp.asarray(v_slab))
        self.active[slot] = _Slot(
            entry=entry, req=req, feed=feed,
            hashes=chain_hashes(feed, bs),
            pending=feed[rec.consumed:], consumed=rec.consumed,
            shared=[], private=private, registered=0, table=table)
        self.sched.mark_running(entry, slot, len(private))
        self._drop_swap(entry, swapped_in=True)
        self.pstats.restored_tokens += rec.consumed
        self.pstats.swap_ins += 1
        self.trace.emit("swap-in", rid=req.rid, slot=slot, tick=self.now,
                        reason="readmit", pages=n_pages,
                        tokens=rec.consumed,
                        pages_in_use=self.alloc.in_use,
                        host_pages_in_use=self.host.in_use)
        self.trace.emit("admit", rid=req.rid, slot=slot, tick=self.now,
                        feed_tokens=len(feed), cached_tokens=0,
                        new_pages=len(private), shared_pages=0,
                        pages_in_use=self.alloc.in_use)
        return True

    def _register_blocks(self, slot: int, st: _Slot) -> None:
        """Publish newly completed full prompt blocks to the prefix cache.
        Kernel mode: the block's KV already lives in the physical page
        its table entry names — registration is pure metadata (first
        writer wins; the loser keeps its private page).  Gather mode:
        copy the slot's rows out to a private page in the host pool."""
        if not self.prefix_enabled:
            return
        bs = self.alloc.block_size
        while (st.registered < len(st.hashes)
               and (st.registered + 1) * bs <= st.consumed):
            h = st.hashes[st.registered]
            if self.kernel == "paged":
                if not self.prefix.contains(h):
                    # table entries at indices >= matched blocks are this
                    # slot's private pages: fully written, never written
                    # again (writes only target rows >= consumed)
                    self.prefix.insert(h, st.table[st.registered])
            elif (not self.prefix.contains(h)
                    and st.reg_cursor < len(st.private)):
                bid = st.private[st.reg_cursor]
                st.reg_cursor += 1
                a, b = st.registered * bs, (st.registered + 1) * bs
                self.pool.write(
                    bid,
                    np.asarray(self.cache["self"]["k"][:, slot, a:b]),
                    np.asarray(self.cache["self"]["v"][:, slot, a:b]))
                self.prefix.insert(h, bid)
            st.registered += 1

    # ------------------------------------------------------ release paths
    def _release(self, st: _Slot) -> None:
        for bid in st.shared:
            self.alloc.decref(bid)
        for bid in st.private:
            self.alloc.decref(bid)   # registered pages survive via cache ref

    def _swap_out(self, st: _Slot, slot: int) -> int:
        """Park the victim's written pages on the host tier.  Returns the
        page count parked (0: swap disabled, nothing written, or tier
        full — the record still rides along so the readmission's
        recompute is attributed).  Shared prefix pages are copied too:
        the record must survive the prefix cache evicting them."""
        rec = _SwapRecord(consumed=st.consumed)
        self._swap_records[st.entry.seq] = rec
        if not self.swap_enabled or st.consumed <= 0:
            return 0
        bs = self.alloc.block_size
        n_pages = pages_for(st.consumed, bs)
        if self.kernel == "paged":
            k_pages = np.stack([np.asarray(_read_page(self.view.k, b))
                                for b in st.table[:n_pages]], axis=1)
            v_pages = np.stack([np.asarray(_read_page(self.view.v, b))
                                for b in st.table[:n_pages]], axis=1)
        else:
            rows = min(n_pages * bs, self.max_len)
            pad = ((0, 0), (0, n_pages * bs - rows), (0, 0), (0, 0))
            k_rows = np.pad(np.asarray(
                _read_slot(self.cache["self"]["k"], slot))[:, :rows], pad)
            v_rows = np.pad(np.asarray(
                _read_slot(self.cache["self"]["v"], slot))[:, :rows], pad)
            k_pages = k_rows.reshape(
                k_rows.shape[0], n_pages, bs, *k_rows.shape[2:])
            v_pages = v_rows.reshape(
                v_rows.shape[0], n_pages, bs, *v_rows.shape[2:])
        ids: list[int] = []
        for i in range(n_pages):
            hid = self.host.put(k_pages[:, i], v_pages[:, i])
            if hid is None:             # tier full: recompute on readmit
                for h in ids:
                    self.host.decref(h)
                return 0
            ids.append(hid)
        rec.host_ids = ids
        self.pstats.swap_outs += 1
        self.trace.emit("swap-out", rid=st.req.rid, slot=slot,
                        tick=self.now, reason="preempt", pages=n_pages,
                        tokens=st.consumed,
                        pages_in_use=self.alloc.in_use,
                        host_pages_in_use=self.host.in_use)
        return n_pages

    def _preempt(self, entry: SchedEntry) -> None:
        st = self.active.pop(entry.slot)
        self.lane.clear(entry.slot)
        self._swap_out(st, entry.slot)
        if self.view is not None:
            self.view.clear_slot(entry.slot)
        self._release(st)
        self.trace.emit("preempt", rid=st.req.rid, slot=entry.slot,
                        tick=self.now, consumed=st.consumed,
                        released_pages=len(st.shared) + len(st.private),
                        pages_in_use=self.alloc.in_use)
        self.sched.mark_preempted(entry)

    def _finish(self, slot: int) -> Request:
        st = self.active.pop(slot)
        self.lane.clear(slot)
        if self.view is not None:
            self.view.clear_slot(slot)
        st.req.finished = True
        st.req.t_done = time.perf_counter()
        self._release(st)
        self.trace.emit("finish", rid=st.req.rid, slot=slot, tick=self.now,
                        tokens_out=len(st.req.out),
                        pages_in_use=self.alloc.in_use)
        self.sched.mark_done(st.entry)
        self.stats.served += 1
        return st.req

    # ------------------------------------------------------------ cancel
    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel at any lifecycle stage.  Mid-prefill / mid-decode the
        slot is freed and every page reference the request held (shared
        prefix refs + private pages) is released; blocks it registered in
        the prefix cache survive through the cache's own reference."""
        entry: SchedEntry = handle.entry
        req = handle.req
        if entry is None or entry.state == DONE or req.cancelled:
            return False
        if entry.state == RUNNING:
            st = self.active.pop(entry.slot)
            self.lane.clear(entry.slot)
            if self.view is not None:
                self.view.clear_slot(entry.slot)
            phase = "prefill" if st.pending else "decode"
            released = len(st.shared) + len(st.private)
            self._release(st)
            self.sched.mark_cancelled(entry)
        elif entry.state == PREEMPTED:
            # mid-lifecycle, not unstarted: the request had consumed
            # tokens before losing its slot, and may hold host-parked
            # pages that must be released with it
            phase, released = "preempted", 0
            self._drop_swap(entry)
            self.sched.mark_cancelled(entry)
        elif entry.state == WAITING:
            phase, released = "waiting", 0
            self.sched.mark_cancelled(entry)
        else:
            return False
        req.cancelled = True
        req.t_done = time.perf_counter()
        self.stats.cancelled += 1
        self.trace.emit("cancel", rid=req.rid, phase=phase, tick=self.now,
                        released_pages=released,
                        pages_in_use=self.alloc.in_use)
        return True

    # --------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """One engine tick: scheduler plan (every ``admit_every``-th
        tick), then one fused chunked decode+sample call."""
        self.now += self.tick_dt
        self._ticks += 1
        run_sched = (self._ticks - 1) % self.admit_every == 0
        admitted = 0
        if run_sched:
            plan = self.sched.schedule(
                free_slots=len(self._free_slots()),
                free_pages=self.alloc.num_free + self.prefix.evictable(),
                cost_fn=self._cost)
            # a candidate's victims are preempted only once its own
            # admission is guaranteed: _admit re-prices the candidate
            # against the pool as it stands NOW (earlier admissions this
            # tick consume free and evictable pages the plan's
            # bookkeeping could not see) and commits the preemptions only
            # after its exact budget check passes, so a failed admission
            # never flushes running work for nothing
            for entry in plan.admit:
                victims = tuple(plan.victims.get(entry.seq, ()))
                if not self._free_slots() and not victims:
                    break
                if not self._admit(entry, victims):
                    break   # intra-tick race: keep strict head-of-line order
                admitted += 1
        else:
            plan = Plan()
        if not self.active:
            if (run_sched and admitted == 0 and not plan.preempt
                    and self.sched.waiting
                    and all(e.arrival <= self.now
                            for e in self.sched.waiting)):
                raise RuntimeError(
                    "paged engine cannot place any waiting request: "
                    f"need more than {self.alloc.num_blocks} pages/"
                    f"{self.slots} slots")
            return []

        toks = np.zeros((self.slots, self.chunk), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        n_new = np.zeros((self.slots,), np.int32)
        need_sample = any(_samples(st.req) for st in self.active.values())
        for slot, st in self.active.items():
            pos[slot] = st.consumed
            if need_sample:          # greedy program never reads the lanes
                self.lane.set(slot, st.req)
            if st.pending:
                n = min(self.chunk, len(st.pending))
                toks[slot, :n] = st.pending[:n]
                n_new[slot] = n
            else:
                toks[slot, 0] = st.next_input
                n_new[slot] = 1

        if self.kernel == "paged":
            pt = jnp.asarray(self.view.page_table)
            if need_sample:
                sampled, self.cache = self._chunk_sample_fn(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(pos), jnp.asarray(n_new), pt,
                    self.lane.as_args())
            else:
                sampled, self.cache = self._chunk_fn(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(pos), jnp.asarray(n_new), pt)
            self.view.adopt(self.cache)
        elif need_sample:
            sampled, self.cache = self._chunk_sample_fn(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos), jnp.asarray(n_new), self.lane.as_args())
        else:
            sampled, self.cache = self._chunk_fn(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos), jnp.asarray(n_new))
        self.stats.decode_steps += 1
        self.stats.observe_occupancy(len(self.active))
        if self.trace.enabled:       # keep the untraced tick allocation-free
            # lane kind comes from pending state, not chunk size: a
            # 1-token final prefill chunk is still a prefill lane
            lanes = [(int(n_new[s]), bool(st.pending))
                     for s, st in self.active.items()]
            self.trace.emit(
                "step", step_kind="chunk", tick=self.now, lanes=len(lanes),
                prefill_lanes=sum(1 for _, p in lanes if p),
                decode_lanes=sum(1 for _, p in lanes if not p),
                prefill_tokens=sum(n for n, p in lanes if p),
                chunk_sizes=tuple(n for n, _ in lanes))
        nxt = np.asarray(sampled)

        finished: list[int] = []
        for slot, st in self.active.items():
            req, n = st.req, int(n_new[slot])
            st.consumed += n
            if st.pending:
                st.pending = st.pending[n:]
                self.pstats.prefill_tokens += n
                self._register_blocks(slot, st)
                if st.pending:
                    continue        # mid-prefill: this lane's sample unused
                # prompt fully consumed this tick: the prefill→decode
                # phase boundary (re-fires after a preempt/readmit
                # recompute, unlike first-token)
                self.trace.emit("prefill-done", rid=req.rid, tick=self.now,
                                slot=slot, consumed=st.consumed)
            tok = int(nxt[slot])
            req.out.append(tok)
            self.stats.tokens_out += 1
            if not req.t_first:
                ttft = self.now - st.entry.arrival
                self.ttft_ticks.append(ttft)
                req.t_first = time.perf_counter()
                self.trace.emit("first-token", rid=req.rid, tick=self.now,
                                ttft_ticks=ttft)
            st.next_input = tok
            if (tok == req.eos_id or len(req.out) >= req.max_new
                    or st.consumed >= self.max_len - 1):
                finished.append(slot)
        return [self._finish(slot) for slot in finished]

    def drain(self) -> list[Request]:
        done: list[Request] = []
        while self.has_work():
            done.extend(self.step())
        return done

    # ---------------------------------------------------------- run shim
    def run(self, requests: list[Request],
            arrivals: list[float] | None = None) -> list[Request]:
        return run_requests(self, requests, arrivals)

    # -------------------------------------------------------------- report
    def report(self) -> dict:
        return {
            "engine": "paged",
            "served": self.stats.served,
            "cancelled": self.stats.cancelled,
            "decode_steps": self.stats.decode_steps,
            "tokens_out": self.stats.tokens_out,
            "mean_batch_occupancy": round(self.stats.mean_occupancy, 2),
            "prefill_tokens": self.pstats.prefill_tokens,
            "cached_tokens": self.pstats.cached_tokens,
            "prefix_hit_rate": round(self.pstats.prefix_hit_rate, 3),
            # prefix-cache internals: the cluster router's summary feed
            # (serve.cluster refreshes per-replica summaries when the
            # resident chain count moves) and the operator's view of
            # cache health without replaying traces
            "prefix_lookups": self.prefix.stats.lookups,
            "prefix_hit_blocks": self.prefix.stats.hit_blocks,
            "prefix_miss_blocks": self.prefix.stats.miss_blocks,
            "prefix_insertions": self.prefix.stats.insertions,
            "prefix_evictions": self.prefix.stats.evictions,
            "prefix_chains": len(self.prefix),
            "page_peak_utilization": round(
                self.alloc.stats.peak_in_use / self.alloc.num_blocks, 3),
            "pages": self.alloc.num_blocks,
            "block_size": self.alloc.block_size,
            "chunk": self.chunk,
            "prefix_cache": self.prefix_enabled,
            "admit_every": self.admit_every,
            "kernel": self.kernel,
            "preemption": self.sched.preemption,
            "preemptions": self.sched.stats.preemptions,
            # host swap tier: the tiering pathway's health signals (the
            # audit layer's pathway-tiering expectations read these)
            "swap": self.swap_enabled,
            "swap_outs": self.pstats.swap_outs,
            "swap_ins": self.pstats.swap_ins,
            "restored_tokens": self.pstats.restored_tokens,
            "recompute_tokens": self.pstats.recompute_tokens,
            "recompute_tokens_saved": self.pstats.restored_tokens,
            "swap_restore_rate": round(self.pstats.swap_restore_rate, 3),
            "prefix_spills": self.prefix.stats.spills,
            "prefix_restores": self.prefix.stats.restores,
            "host_pages": self.host.capacity,
            "host_pages_in_use": self.host.in_use,
            "host_page_peak": self.host.stats.peak_in_use,
            # worst per-program count (greedy / sampled variants each
            # bound at one compile; see ServeEngine.report)
            "compiles": max(self._chunk_fn.compiles,
                            self._chunk_sample_fn.compiles),
        }


# ================================================================= oracle


def token_matrix(done: list[Request], n_requests: int,
                 max_new: int) -> np.ndarray:
    """Output streams as a dense int matrix (pad = -1), rid-ordered so
    completion order does not affect the comparison."""
    out = np.full((n_requests, max_new), -1, np.int64)
    for r in done:
        out[r.rid, :len(r.out)] = r.out
    return out


def compare_engines(model: Model, params: Any,
                    make_requests: Callable[[], list[Request]], *,
                    slots: int = 2, max_len: int = 64, block_size: int = 8,
                    chunk: int = 4, repeats: int = 1,
                    sampling: SamplingParams | None = None,
                    engine_kwargs: dict[str, dict] | None = None,
                    cluster: dict | None = None):
    """The paged engine's correctness proof, in the paper's methodology:
    the same workload under two environments (contiguous oracle vs paged)
    must agree token-for-token.  With ``sampling`` given, both engines
    decode the workload under those SamplingParams — counter-based keys
    make sampled streams engine-independent, so the verdict is the same
    bit-identity as greedy.

    ``engine_kwargs`` pins per-engine construction explicitly instead of
    relying on defaults/globals: ``{"contiguous": {...}, "paged": {...}}``
    — e.g. ``{"paged": {"kernel": "gather"}}`` holds the oracle verdict
    over the dense-fallback pathway while ``{"paged": {"kernel":
    "paged"}}`` pins the Pallas page-table kernel on.

    With ``cluster`` given (a dict of ``ClusterEngine`` kwargs, e.g.
    ``{"replicas": 3, "routing": "random"}``), the comparison becomes
    single paged engine vs a ``ClusterEngine`` over the same geometry:
    routing moves requests between replicas but counter-based sampling
    keys on ``(seed, rid, step)``, so a cluster of any size — under ANY
    routing policy — must reproduce the single engine's streams exactly.
    This is the routing-correctness oracle: a router that corrupted,
    duplicated, or dropped a request would break bit-identity here.

    Returns a core.verify.DualEnvReport whose verdicts CI gates on."""
    from repro.core.verify import DualEnvHarness

    ek = engine_kwargs or {}
    contig_kw = dict(ek.get("contiguous", {}))
    paged_kw = dict(ek.get("paged", {}))

    def requests() -> list[Request]:
        reqs = make_requests()
        if sampling is not None:
            for r in reqs:
                r.sampling = sampling
        return reqs

    probe = requests()
    n, max_new = len(probe), max(r.max_new for r in probe)

    def run_contiguous():
        eng = ServeEngine(model, params, slots=slots, max_len=max_len,
                          **contig_kw)
        return token_matrix(eng.run(requests()), n, max_new)

    def run_paged():
        eng = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                               block_size=block_size, chunk=chunk,
                               **paged_kw)
        return token_matrix(eng.run(requests()), n, max_new)

    harness = DualEnvHarness(repeats=repeats, warmup=0)
    if cluster is not None:
        # routing oracle: single paged engine vs the cluster router
        from repro.serve.cluster import ClusterEngine  # local: avoid cycle

        cluster_kw = dict(cluster)

        def run_cluster():
            eng = ClusterEngine(model, params, slots=slots, max_len=max_len,
                                block_size=block_size, chunk=chunk,
                                **cluster_kw)
            return token_matrix(eng.run(requests()), n, max_new)

        return harness.compare("paged", run_paged,
                               "cluster", run_cluster, rtol=1e-9, atol=0.5)
    return harness.compare("contiguous", run_contiguous,
                           "paged", run_paged, rtol=1e-9, atol=0.5)
