#!/usr/bin/env bash
# The one CI gate: emit-kind lint, tier-1 tests, full smoke harness.
#
#   scripts/ci.sh [--artifacts-dir DIR]
#
# Three stages, fail-fast, cheapest first:
#   1. emit-kind lint — every tracer.emit(kind) in src/, benchmarks/,
#      and scripts/ must be declared in audit.trace.KNOWN_KINDS
#   2. tier-1 pytest  — the full unit/integration suite (-x -q)
#   3. smoke_all      — every family forward/train/prefill/decode plus
#      the serving, audit-pathway, workload-SLO, cluster, and
#      KV-tiering benchmarks (swap-restore must be token-exact and
#      ledger a positive restore rate) and the timeline determinism
#      gate (same seed must render a byte-identical /timeline Chrome
#      trace with exact phase-share sums), gated on Diagnostics
#      findings (ledger orphans + perf trend included); --json keeps
#      the machine-readable report on stdout
# Any extra arguments (e.g. --artifacts-dir DIR) pass through to
# scripts/smoke_all.py.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== ci 1/3: emit-kind lint =="
python -m pytest -q \
    "tests/test_audit.py::test_emitted_kinds_are_declared_in_known_kinds"

echo "== ci 2/3: tier-1 pytest =="
python -m pytest -x -q

echo "== ci 3/3: smoke_all =="
python scripts/smoke_all.py --json "$@"

echo "== ci: all gates green =="
