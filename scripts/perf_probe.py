"""Perf-iteration probe: lower one cell under a rules/remat variant, print
the three roofline terms + the top collectives with their HLO context.

    PYTHONPATH=src python scripts/perf_probe.py ARCH SHAPE [--rules X]
        [--remat X] [--mb N] [--top N] [--save-hlo PATH]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import sys

import jax

from repro.configs import ALL_ARCHS, SHAPES
from repro.configs.base import RunConfig, TrainConfig
from repro.core.inspector import hlo_cost, parse_hlo
from repro.launch.bind import abstract_cell
from repro.launch.dryrun import _default_microbatches
from repro.launch.mesh import make_production_mesh, mesh_config
from repro.models import build
from repro.parallel import bind as ctx_bind, rules_for

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def probe(arch, shape_name, rules="auto", remat="full", mb=None,
          multi_pod=False, top=10, save_hlo=None):
    cfg = ALL_ARCHS[arch]
    shape = SHAPES[shape_name]
    if mb is None:
        mb = _default_microbatches(cfg, shape)
    run = RunConfig(model=cfg, shape=shape,
                    mesh=mesh_config(multi_pod=multi_pod), rules=rules,
                    train=TrainConfig(remat=remat, microbatches=mb))
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(cfg)
    with ctx_bind(mesh, rules_for(run)):
        fn, args, shards, out_sh, donate = abstract_cell(model, run, mesh)
        compiled = jax.jit(fn, in_shardings=shards, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
    hlo = compiled.as_text()
    if save_hlo:
        open(save_hlo, "w").write(hlo)
    m = compiled.memory_analysis()
    mem = (m.argument_size_in_bytes + m.temp_size_in_bytes
           + m.output_size_in_bytes - m.alias_size_in_bytes)
    hc = hlo_cost(hlo)
    rep = parse_hlo(hlo, mesh.devices.size)
    t_c, t_m, t_x = (hc["dot_flops"] / PEAK, hc["bytes"] / HBM,
                     rep.total_moved_bytes / ICI)
    print(f"== {arch} × {shape_name} rules={rules} remat={remat} mb={mb} "
          f"{'mp' if multi_pod else 'sp'} ==")
    print(f"terms: compute={t_c:.3f}s memory={t_m:.3f}s "
          f"collective={t_x:.3f}s  mem/dev={mem/2**30:.2f}GiB")
    print(f"moved by kind: "
          f"{ {k: f'{v/2**30:.1f}GiB' for k, v in rep.by_kind().items()} }")
    ops = sorted(rep.ops, key=lambda o: -o.moved_bytes)[:top]
    for o in ops:
        print(f"  {o.kind:18s} {o.payload_bytes/2**20:9.1f}MiB g={o.group_size:3d} "
              f"x{o.trips:4d} -> {o.moved_bytes/2**30:7.2f}GiB  "
              f"{o.computation[:34]:34s} {o.name}")
    return dict(t_c=t_c, t_m=t_m, t_x=t_x, mem=mem)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--rules", default="auto")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--mb", type=int, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--save-hlo", default=None)
    a = ap.parse_args()
    probe(a.arch, a.shape, a.rules, a.remat, a.mb, a.multi_pod, a.top,
          a.save_hlo)
