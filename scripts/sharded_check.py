"""Dev harness: 8-device sharded lower+compile+run for reduced configs,
and numeric parity sharded-vs-single-device.  Run in a subprocess."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, reduced, ShapeConfig
from repro.configs.base import RunConfig, TrainConfig
from repro.launch.bind import abstract_cell, batch_shardings, param_shardings
from repro.models import build
from repro.parallel import bind as ctx_bind, rules_for
from repro.train.step import init_train_state, make_train_step

names = sys.argv[1:] or list(ALL_ARCHS)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 3)

for name in names:
    cfg = reduced(ALL_ARCHS[name])
    model = build(cfg)
    key = jax.random.PRNGKey(0)

    # ---- single-device reference ----
    shape = ShapeConfig("t", "train", 32, 4)
    batch = model.sample_batch(shape, key)
    params = model.init_params(key)
    ref_loss, _ = jax.jit(lambda p, b: model.loss(p, b))(params, batch)

    # ---- sharded ----
    run = RunConfig(model=cfg, shape=shape, train=TrainConfig(remat="full"))
    with ctx_bind(mesh, rules_for(run)):
        psh = param_shardings(model, mesh)
        bsh = batch_shardings(model, shape, mesh)
        params_s = jax.device_put(params, psh)
        batch_s = jax.device_put(batch, bsh)
        loss_s, _ = jax.jit(lambda p, b: model.loss(p, b))(params_s, batch_s)
        # full train step compile + run
        state = init_train_state(model, key)
        fn, args, shards, out_shards, donate = abstract_cell(model, run, mesh)
        step = jax.jit(fn, in_shardings=shards, out_shardings=out_shards,
                       donate_argnums=donate)
        state_s = jax.device_put(state, shards[0])
        st2, m = step(state_s, batch_s)
        # decode cell
        drun = RunConfig(model=cfg, shape=ShapeConfig("d", "decode", 32, 8),
                         rules="serve")
        with ctx_bind(mesh, rules_for(drun)):
            fn, dargs, dshards, dout, ddonate = abstract_cell(model, drun, mesh)
            lowered = jax.jit(fn, in_shardings=dshards, out_shardings=dout,
                              donate_argnums=ddonate).lower(*dargs)
            compiled = lowered.compile()

    err = abs(float(loss_s) - float(ref_loss))
    status = "OK " if err < 2e-2 else "FAIL"
    print(f"{status} {name:24s} ref={float(ref_loss):.4f} "
          f"sharded={float(loss_s):.4f} err={err:.2e} "
          f"step_loss={float(m['loss']):.4f}")
print("DONE")
