"""Dev harness: tiny forward/train/prefill/decode for every family on CPU,
plus the serving-throughput smoke gated on its diagnostics findings."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, SHAPES, reduced, ShapeConfig
from repro.models import build
from repro.train.step import init_train_state, make_train_step
from repro.configs.base import RunConfig, TrainConfig

names = sys.argv[1:] or list(ALL_ARCHS)
shape = ShapeConfig("smoke", "train", 32, 2)

for name in names:
    cfg = reduced(ALL_ARCHS[name])
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    n = sum(x.size for x in jax.tree.leaves(params))
    batch = model.sample_batch(shape, key)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert jnp.isfinite(loss), (name, loss)

    # one train step
    run = RunConfig(model=cfg, shape=shape, train=TrainConfig(remat="full"))
    state = init_train_state(model, key)
    step = jax.jit(make_train_step(model, run))
    state2, m = step(state, batch)
    assert jnp.isfinite(m["loss"]), name

    # prefill + decode
    pb = model.sample_batch(ShapeConfig("smoke", "prefill", 32, 2), key)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_len=32))(params, pb)
    assert logits.shape == (2, cfg.padded_vocab), (name, logits.shape)
    cache2 = model.zero_cache(2, 32)
    # sizes line up?
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 (_ for _ in ()).throw(AssertionError((name, a.shape, b.shape))),
                 cache, cache2)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.full((2,), 31, jnp.int32)
    dl, cache3 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert dl.shape == (2, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(dl)), name
    print(f"OK {name:24s} params={n:>10,} loss={float(loss):.3f} "
          f"step_loss={float(m['loss']):.3f}")

# serve throughput smoke: paged-vs-contiguous oracle + speedup, folded
# into the diagnostics gate (the paper's performance-verified-image bar:
# an error finding fails the harness)
from repro.core.diagnostics import Diagnostics  # noqa: E402

repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
out = subprocess.run(
    [sys.executable, os.path.join(repo, "benchmarks", "serve_throughput.py"),
     "--smoke"], capture_output=True, text=True, cwd=repo)
assert out.returncode == 0, out.stderr[-2000:]
rec = json.loads(out.stdout.strip().splitlines()[-1])
diag = Diagnostics()
diag.extend(rec["findings"], source="serve_throughput")
print(diag.render())
assert diag.gate(), "serve throughput diagnostics gate failed"
print(f"OK serve_throughput        speedup={rec['speedup']}x "
      f"oracle_ok={rec['oracle_ok']} "
      f"hit_rate={rec['paged']['prefix_hit_rate']}")
print("ALL OK")
