"""Dev harness: tiny forward/train/prefill/decode for every family on CPU,
plus the serving-throughput, audit-pathway, workload-SLO,
cluster-scaling, and KV-tiering smokes gated on their diagnostics
findings, a timeline
determinism check (same seed + trace must render a byte-identical
``/timeline`` Chrome-trace body, mirroring the ``/metrics``
byte-identity gate), a ledger integrity audit (orphan ``BENCH_*.json``
files are errors), and the rolling-median throughput trend over ledger
history (a collapse beyond ``TREND_FACTOR`` is a warn-level finding).

    PYTHONPATH=src python scripts/smoke_all.py [archs...] [--json]
        [--ledger-dir DIR] [--update-baseline] [--artifacts-dir DIR]

``--json`` prints one machine-readable report (per-arch results, all
findings, ledger deltas) on stdout's last line; the exit code is driven
by ``Diagnostics.gate()`` either way — the paper's performance-verified
bar, where an error finding fails the harness.

``--artifacts-dir DIR`` publishes the run's evidence for CI archiving:
the ``BENCH_*.json`` perf-ledger files (baselines + bounded history) and
the machine-readable report, so a perf regression can be bisected across
PRs from build artifacts alone (ROADMAP PR 2 follow-up).
"""
import argparse
import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, ShapeConfig, reduced
from repro.configs.base import RunConfig, TrainConfig
from repro.core.diagnostics import Diagnostics
from repro.models import build
from repro.train.step import init_train_state, make_train_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Benchmarks this harness runs, in order.  Their ``<name>_{smoke,full}``
#: keys are the only ledger files allowed to exist in the ledger dir —
#: ``Ledger.audit_owned`` flags anything else as an orphan (a baseline
#: nobody maintains silently attests metrics nothing measures).
BENCHES = ["serve_throughput", "audit_pathways", "serve_workloads",
           "serve_cluster", "serve_tiering"]

#: In-process checks that also own ledger keys (no benchmarks/ script):
#: the timeline determinism gate below ledgers its deterministic
#: counters under ``serve_timeline_smoke``.
EXTRA_LEDGER_BENCHES = ["serve_timeline"]

#: Throughput-trend regression factor: the latest ungated wall-clock
#: throughput sample dropping below median/TREND_FACTOR over the ledger
#: history window is a warn-level ``perf-trend`` finding — wall time on
#: shared CI is too noisy to gate run-to-run, but a sustained halving
#: against the rolling median is a real trajectory signal, not noise.
TREND_FACTOR = 1.5


def owned_ledger_keys(benches=None) -> list[str]:
    return [f"{b}_{mode}"
            for b in (benches or BENCHES + EXTRA_LEDGER_BENCHES)
            for mode in ("smoke", "full")]


def timeline_smoke(ledger_dir: str, update_baseline: bool) -> dict:
    """Timeline determinism gate: run the same seeded bursty trace twice
    through a fresh paged engine + tracer + log and require the
    ``/timeline`` endpoint to render byte-identical, Perfetto-loadable
    Chrome-trace JSON, with every closed request's phase shares summing
    to exactly 1.  Ledgers the deterministic counts under
    ``serve_timeline_smoke``; returns the report record (``findings``
    inside, same contract as the benchmark scripts)."""
    from repro.audit import (EventLog, Ledger, MetricSpec, MetricsServer,
                             ServeMetrics, Tracer, build_timelines)
    from repro.serve import PagedServeEngine, WorkloadSpec, generate

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    trace = generate(WorkloadSpec(
        name="timeline-smoke", family="chat", arrival="bursty",
        n_requests=6, vocab_size=cfg.vocab_size, seed=13, max_new=4,
        prefix_len=8, n_streams=2, suffix_lo=2, suffix_hi=4,
        burst_size=3, burst_gap=8.0, priorities=(0, 1)))

    def run_once():
        tracer = Tracer()
        log = EventLog()
        tracer.subscribe(log.append)
        metrics = ServeMetrics()
        metrics.attach(tracer)
        eng = PagedServeEngine(model, params, slots=2, max_len=48,
                               block_size=8, chunk=4, tracer=tracer)
        eng.run(trace.requests(), arrivals=list(trace.arrivals))
        status, _, body = MetricsServer(
            metrics.registry, log).handle("/timeline")
        return status, body, log

    status, body1, log = run_once()
    _, body2, _ = run_once()
    findings: list[dict] = []
    if body1 != body2:
        findings.append({
            "severity": "error", "kind": "timeline-nondeterminism",
            "detail": "two same-seed runs rendered different /timeline "
                      "bodies: wall-clock state leaked into the "
                      "Chrome-trace export"})
    doc = json.loads(body1)
    valid = (status == 200 and isinstance(doc.get("traceEvents"), list)
             and bool(doc["traceEvents"])
             and all("ph" in e and "pid" in e for e in doc["traceEvents"]))
    if not valid:
        findings.append({
            "severity": "error", "kind": "timeline-invalid",
            "detail": "/timeline body is not valid Chrome trace-event "
                      "JSON (traceEvents list with ph/pid per event)"})
    timelines = build_timelines(log)
    closed = [tl for tl in timelines.values() if tl.end is not None]
    exact = bool(closed) and all(sum(tl.shares().values()) == 1
                                 for tl in closed)
    if not exact:
        findings.append({
            "severity": "error", "kind": "timeline-inexact",
            "detail": "per-request phase shares do not sum to exactly 1 "
                      "on the smoke trace"})

    ledger = Ledger(ledger_dir)
    metrics_l = {
        "timeline_requests": float(len(timelines)),
        "timeline_events": float(len(doc["traceEvents"])),
        "timeline_bytes": float(len(body1)),
        "share_sum_exact": 1.0 if exact else 0.0,
    }
    specs = [MetricSpec(n, higher_is_better=True, rel_tol=0.0)
             for n in metrics_l]
    res = ledger.compare("serve_timeline_smoke", metrics_l, specs,
                         update_baseline=update_baseline)
    findings.extend(res.findings)
    return {
        "deterministic": body1 == body2,
        "valid_chrome_trace": valid,
        "share_sum_exact": exact,
        "requests": len(timelines),
        "events": len(doc["traceEvents"]),
        "bytes": len(body1),
        "ledger": {"baseline_written": res.baseline_written,
                   "deltas": res.deltas,
                   "path": str(ledger.path("serve_timeline_smoke"))},
        "findings": findings,
    }


def smoke_arch(name: str) -> dict:
    cfg = reduced(ALL_ARCHS[name])
    shape = ShapeConfig("smoke", "train", 32, 2)
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    n = sum(x.size for x in jax.tree.leaves(params))
    batch = model.sample_batch(shape, key)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert jnp.isfinite(loss), (name, loss)

    # one train step
    run = RunConfig(model=cfg, shape=shape, train=TrainConfig(remat="full"))
    state = init_train_state(model, key)
    step = jax.jit(make_train_step(model, run))
    state2, m = step(state, batch)
    assert jnp.isfinite(m["loss"]), name

    # prefill + decode
    pb = model.sample_batch(ShapeConfig("smoke", "prefill", 32, 2), key)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=32))(params, pb)
    assert logits.shape == (2, cfg.padded_vocab), (name, logits.shape)
    cache2 = model.zero_cache(2, 32)
    # sizes line up?
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 (_ for _ in ()).throw(AssertionError((name, a.shape, b.shape))),
                 cache, cache2)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.full((2,), 31, jnp.int32)
    dl, cache3 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert dl.shape == (2, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(dl)), name
    return {"arch": name, "params": int(n), "loss": float(loss),
            "step_loss": float(m["loss"])}


def run_bench(script: str, extra: list[str]) -> dict:
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", script),
         "--smoke"] + extra,
        capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, (script, out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("archs", nargs="*", default=None)
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on the last stdout line")
    ap.add_argument("--ledger-dir", default=REPO,
                    help="BENCH_*.json directory for the perf ledger")
    ap.add_argument("--update-baseline", action="store_true")
    ap.add_argument("--artifacts-dir", default=None,
                    help="copy the BENCH_*.json ledgers and the json "
                         "report here (CI build artifacts)")
    args = ap.parse_args()
    names = args.archs or list(ALL_ARCHS)
    quiet = args.as_json

    archs = []
    for name in names:
        rec = smoke_arch(name)
        archs.append(rec)
        if not quiet:
            print(f"OK {name:24s} params={rec['params']:>10,} "
                  f"loss={rec['loss']:.3f} step_loss={rec['step_loss']:.3f}")

    # serving + audit smokes: findings fold into the one diagnostics gate
    diag = Diagnostics()
    ledger_flags = ["--ledger-dir", args.ledger_dir] + (
        ["--update-baseline"] if args.update_baseline else [])

    serve_rec = run_bench("serve_throughput.py", ledger_flags)
    diag.extend(serve_rec["findings"], source="serve_throughput")

    audit_rec = run_bench("audit_pathways.py", ledger_flags)
    diag.extend(audit_rec["findings"], source="audit_pathways")

    workloads_rec = run_bench("serve_workloads.py", ledger_flags)
    diag.extend(workloads_rec["findings"], source="serve_workloads")

    cluster_rec = run_bench("serve_cluster.py", ledger_flags)
    diag.extend(cluster_rec["findings"], source="serve_cluster")

    tiering_rec = run_bench("serve_tiering.py", ledger_flags)
    diag.extend(tiering_rec["findings"], source="serve_tiering")

    timeline_rec = timeline_smoke(args.ledger_dir, args.update_baseline)
    diag.extend(timeline_rec["findings"], source="serve_timeline")

    ledger_deltas = {
        "serve_throughput": serve_rec.get("ledger"),
        "audit_pathways": audit_rec.get("ledger"),
        "serve_workloads": workloads_rec.get("ledger"),
        "serve_cluster": cluster_rec.get("ledger"),
        "serve_tiering": tiering_rec.get("ledger"),
        "serve_timeline": timeline_rec.get("ledger"),
    }

    # ledger integrity + trend: orphan BENCH files are errors; the
    # rolling median of the ungated wall-clock throughput is the
    # trajectory signal the per-run numbers are too noisy to carry —
    # and a latest sample collapsing below median/TREND_FACTOR is a
    # warn-level finding, not just a printout
    from repro.audit import Ledger

    ledger = Ledger(args.ledger_dir)
    diag.extend(ledger.audit_owned(owned_ledger_keys()),
                source="ledger-integrity")
    throughput_trend = ledger.rolling_median(
        "serve_throughput_smoke", "paged_tokens_per_s")
    if throughput_trend and throughput_trend["n"] >= 3:
        median, latest = throughput_trend["median"], throughput_trend["latest"]
        if median > 0 and latest < median / TREND_FACTOR:
            diag.extend([{
                "severity": "warn", "kind": "perf-trend",
                "detail": f"paged_tokens_per_s latest {latest} fell below "
                          f"median {median} / {TREND_FACTOR} over the last "
                          f"{throughput_trend['n']} ledger entries: "
                          f"sustained throughput regression"}],
                source="ledger-trend")
    ok = diag.gate()

    report = {
        "ok": ok,
        "worst": diag.worst,
        "archs": archs,
        "serve_throughput": {
            k: serve_rec[k] for k in
            ("speedup", "oracle_ok", "contiguous_tokens_per_s",
             "paged_tokens_per_s", "kernel_parity_ok",
             "kernel_vs_gather_speedup")},
        "audit_pathways": {
            "oracle_ok": audit_rec["oracle_ok"],
            "detected_all": audit_rec["detected_all"],
            "lifecycle": audit_rec.get("lifecycle"),
            "metrics": audit_rec["metrics"]},
        "serve_workloads": {
            "oracle_ok": workloads_rec["oracle_ok"],
            "slo_ok": workloads_rec["slo_ok"],
            "families": [{
                "workload": f["workload"]["workload"],
                "p99_ttft_ticks": f["p99_ttft_ticks"],
                "p99_decode_gap_ticks": f["p99_decode_gap_ticks"],
                "prefix_hit_rate": f["report"]["prefix_hit_rate"],
            } for f in workloads_rec["families"]]},
        "serve_cluster": {
            "oracle_ok": cluster_rec["oracle_ok"],
            "scaling_rmax": cluster_rec["scaling_rmax"],
            "routed_affinity": cluster_rec["routed_affinity"],
            "shared_hit_rate": cluster_rec["shared_hit_rate"],
            "replica_sweep": cluster_rec["replica_sweep"]},
        "serve_tiering": {
            "oracle_ok": tiering_rec["oracle_ok"],
            "exact_vs_reference": tiering_rec["exact_vs_reference"],
            "swap_restore_rate": tiering_rec["swap"]["swap_restore_rate"],
            "recompute_tokens_saved": tiering_rec["recompute_tokens_saved"],
            "preemptions": tiering_rec["swap"]["preemptions"]},
        "serve_timeline": {
            k: timeline_rec[k] for k in
            ("deterministic", "valid_chrome_trace", "share_sum_exact",
             "requests", "events", "bytes")},
        "paged_tokens_per_s_trend": throughput_trend,
        "findings": diag.findings,
        "ledger": ledger_deltas,
    }

    if args.artifacts_dir:
        adir = Path(args.artifacts_dir)
        adir.mkdir(parents=True, exist_ok=True)
        copied = []
        for f in sorted(Path(args.ledger_dir).glob("BENCH_*.json")):
            shutil.copy2(f, adir / f.name)
            copied.append(f.name)
        # metadata goes in before writing, so the archived report itself
        # names the ledgers that accompany it
        report["artifacts"] = {"dir": str(adir),
                               "ledgers": copied,
                               "report": "smoke_report.json"}
        (adir / "smoke_report.json").write_text(json.dumps(report, indent=1))

    if quiet:
        print(json.dumps(report))
    else:
        print(diag.render())
        print(f"OK serve_throughput        speedup={serve_rec['speedup']}x "
              f"oracle_ok={serve_rec['oracle_ok']} "
              f"hit_rate={serve_rec['paged']['prefix_hit_rate']} "
              f"kernel_parity={serve_rec['kernel_parity_ok']} "
              f"kernel_vs_gather={serve_rec['kernel_vs_gather_speedup']}x")
        print(f"OK audit_pathways          "
              f"detected_all={audit_rec['detected_all']} "
              f"oracle_ok={audit_rec['oracle_ok']}")
        print(f"OK serve_workloads         "
              f"slo_ok={workloads_rec['slo_ok']} "
              f"oracle_ok={workloads_rec['oracle_ok']}")
        print(f"OK serve_cluster           "
              f"rmax={cluster_rec['scaling_rmax']} "
              f"affinity={cluster_rec['routed_affinity']} "
              f"shared_hit={cluster_rec['shared_hit_rate']} "
              f"oracle_ok={cluster_rec['oracle_ok']}")
        print(f"OK serve_tiering           "
              f"restore_rate={tiering_rec['swap']['swap_restore_rate']} "
              f"saved={tiering_rec['recompute_tokens_saved']} "
              f"exact={tiering_rec['exact_vs_reference']} "
              f"oracle_ok={tiering_rec['oracle_ok']}")
        print(f"OK serve_timeline          "
              f"deterministic={timeline_rec['deterministic']} "
              f"valid={timeline_rec['valid_chrome_trace']} "
              f"share_sum_exact={timeline_rec['share_sum_exact']} "
              f"requests={timeline_rec['requests']}")
        if throughput_trend:
            print(f"   paged_tokens_per_s     "
                  f"median={throughput_trend['median']} "
                  f"over n={throughput_trend['n']} "
                  f"latest={throughput_trend['latest']}")
        print("ALL OK" if ok else "GATE FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
