"""Dev harness: tiny forward/train/prefill/decode for every family on CPU."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, SHAPES, reduced, ShapeConfig
from repro.models import build
from repro.train.step import init_train_state, make_train_step
from repro.configs.base import RunConfig, TrainConfig

names = sys.argv[1:] or list(ALL_ARCHS)
shape = ShapeConfig("smoke", "train", 32, 2)

for name in names:
    cfg = reduced(ALL_ARCHS[name])
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    n = sum(x.size for x in jax.tree.leaves(params))
    batch = model.sample_batch(shape, key)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert jnp.isfinite(loss), (name, loss)

    # one train step
    run = RunConfig(model=cfg, shape=shape, train=TrainConfig(remat="full"))
    state = init_train_state(model, key)
    step = jax.jit(make_train_step(model, run))
    state2, m = step(state, batch)
    assert jnp.isfinite(m["loss"]), name

    # prefill + decode
    pb = model.sample_batch(ShapeConfig("smoke", "prefill", 32, 2), key)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_len=32))(params, pb)
    assert logits.shape == (2, cfg.padded_vocab), (name, logits.shape)
    cache2 = model.zero_cache(2, 32)
    # sizes line up?
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 (_ for _ in ()).throw(AssertionError((name, a.shape, b.shape))),
                 cache, cache2)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.full((2,), 31, jnp.int32)
    dl, cache3 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert dl.shape == (2, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(dl)), name
    print(f"OK {name:24s} params={n:>10,} loss={float(loss):.3f} "
          f"step_loss={float(m['loss']):.3f}")
print("ALL OK")
