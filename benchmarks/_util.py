"""Benchmark helpers: subprocess multi-device runs + timing."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time
from typing import Any, Callable

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(code: str, n_devices: int, timeout: int = 600) -> dict:
    """Run `code` in a subprocess with n placeholder CPU devices; the code
    must print one JSON object on its last line."""
    pre = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices}'\n"
        "import sys; sys.path.insert(0, 'src')\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", pre + textwrap.dedent(code)],
        capture_output=True, text=True, cwd=REPO, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def time_call(fn: Callable[[], Any], repeats: int = 5, warmup: int = 2) -> dict:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return {"mean_s": sum(times) / len(times), "min_s": min(times),
            "max_s": max(times)}


# TPU v5e model constants (per chip / per link)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ICI_LAT = 1e-6
