"""KV memory tiering: swap-restore vs recompute under preemption pressure.

The host swap tier's value proposition, measured: a preemption-heavy
priority trace runs on a tight single-digit-page pool three ways —

  * **reference**: ample slots, no interference (the uninterrupted
    streams every constrained run must reproduce);
  * **swap on**: preempted requests park their written pages in the host
    tier and readmission swaps them back (no re-prefill);
  * **swap off**: every readmission re-prefills prompt + generated
    tokens from scratch (the PR-4 recompute pathway, now the costed
    fallback).

Correctness first: ``compare_engines`` (greedy AND sampled) must stay
green with the tier on, and both constrained runs must emit exactly the
reference streams — swap restore is bit-exact (the restored rows ARE the
rows an uninterrupted run wrote), recompute is the established
equivalence.  Then the contrast: the swap run's ``restored_tokens``
(= ``recompute_tokens_saved``) and ``swap_restore_rate`` go into the
persisted ledger with tight bands, the re-prefill chunk steps the
no-swap run wastes are reported, and wall-clock throughput is tracked
ungated.

    PYTHONPATH=src python benchmarks/serve_tiering.py [--smoke]
        [--ledger-dir DIR] [--update-baseline]

Prints one JSON object on the last line.  ``findings`` carries the
machine-checkable diagnostics records scripts/smoke_all.py folds into
the CI gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

try:  # run as a module (benchmarks.run) or as a script
    from benchmarks.serve_throughput import (PAGED_COUNTER_SPECS,
                                             paged_counter_metrics)
except ImportError:  # pragma: no cover - script path
    from serve_throughput import PAGED_COUNTER_SPECS, paged_counter_metrics


def _tier_trace(vocab: int, *, n_low: int, n_high: int, low_max_new: int,
                high_max_new: int, seed: int):
    """Preemption bait: long low-priority requests saturate the slots,
    staggered pairs of short high-priority requests arrive later and
    evict them — twice, so readmitted lows are preempted *again* with
    more written pages parked each time."""
    from repro.serve.engine import Request

    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=12).tolist()
    tails = [rng.integers(0, vocab, size=int(rng.integers(3, 7))).tolist()
             for _ in range(n_low + n_high)]

    def make() -> list:
        reqs = [Request(rid=i, prompt=prefix + tails[i],
                        max_new=low_max_new, priority=0)
                for i in range(n_low)]
        reqs += [Request(rid=n_low + j, prompt=prefix + tails[n_low + j],
                         max_new=high_max_new, priority=5)
                 for j in range(n_high)]
        return reqs

    # lows at t=0; highs in two waves so the lows resume in between
    arrivals = [0.0] * n_low
    wave_gap = 6.0 + 3.0 * low_max_new / 4
    for j in range(n_high):
        arrivals.append(8.0 + 2.0 * (j % (n_high // 2))
                        + wave_gap * (j // (n_high // 2)))
    return make, arrivals


def _timed_run(eng, reqs, arrivals):
    t0 = time.perf_counter()
    for req, arr in zip(reqs, arrivals):
        eng.submit(req, arrival=arr)
    done = eng.drain()
    return time.perf_counter() - t0, done


def bench(arch: str = "deepseek-7b", *, smoke: bool = False, seed: int = 0,
          ledger_dir: str | None = None,
          update_baseline: bool = False) -> dict:
    from repro.audit import AuditContext, Ledger, MetricSpec, RunAudit
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve import SamplingParams
    from repro.serve.engine import (PagedServeEngine, compare_engines,
                                    token_matrix)

    if smoke:
        n_low, n_high, low_max_new, high_max_new = 2, 4, 20, 4
        slots, max_len, block, chunk, blocks = 2, 64, 4, 4, 24
    else:
        n_low, n_high, low_max_new, high_max_new = 3, 6, 28, 6
        slots, max_len, block, chunk, blocks = 3, 96, 4, 4, 48

    cfg = reduced(ALL_ARCHS[arch])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    make, arrivals = _tier_trace(cfg.vocab_size, n_low=n_low, n_high=n_high,
                                 low_max_new=low_max_new,
                                 high_max_new=high_max_new, seed=seed)
    n_req = n_low + n_high
    findings: list[dict] = []

    # ------- correctness: the dual-environment verdict with the tier on
    sampled = SamplingParams(temperature=0.8, top_k=20, top_p=0.95,
                             seed=seed + 1)
    oracle_ok: dict[str, bool] = {}
    for mode, sp in (("greedy", None), ("sampled", sampled)):
        verify = compare_engines(model, params, make, slots=slots,
                                 max_len=max_len, block_size=block,
                                 chunk=chunk, sampling=sp)
        oracle_ok[mode] = verify.ok
        for v in verify.verdicts:
            if not v.ok:
                findings.append({"severity": "error",
                                 "kind": f"serve-oracle-{mode}-{v.kind}",
                                 "detail": v.detail})

    # ------- reference: enough slots for everyone, nothing preempted
    ref = PagedServeEngine(model, params, slots=n_req, max_len=max_len,
                           block_size=block, chunk=chunk)
    _, ref_done = _timed_run(ref, make(), arrivals)
    ref_tokens = token_matrix(ref_done, n_req, low_max_new)
    if ref.report()["preemptions"] != 0:  # the contrast needs a clean ref
        findings.append({
            "severity": "error", "kind": "tiering-reference-preempted",
            "detail": "ample reference engine preempted: trace geometry "
                      "no longer isolates the swap pathway"})

    # ------- the contrast: same tight engine, tier on vs off
    from repro.serve.engine import Request

    def tight_run(swap: bool):
        audit = RunAudit(AuditContext(workload="bench:serve_tiering",
                                      family=cfg.family, arch=cfg.name,
                                      shared_prefix=True))
        eng = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                               block_size=block, chunk=chunk,
                               num_blocks=blocks, swap=swap,
                               tracer=audit.tracer)
        # compile warm-up on disjoint prompts, then rewind the tick clock
        # so the measured arrivals mean what they say
        warm_rng = np.random.default_rng(seed + 99)
        eng.run([Request(rid=10_000 + i,
                         prompt=warm_rng.integers(
                             0, cfg.vocab_size, 6).tolist(), max_new=2)
                 for i in range(slots)])
        eng.now = 0.0
        eng.ttft_ticks.clear()
        wall, done = _timed_run(eng, make(), arrivals)
        return audit, eng, wall, token_matrix(done, n_req, low_max_new)

    sw_audit, sw_eng, sw_wall, sw_tokens = tight_run(swap=True)
    sw_rep = sw_eng.report()
    findings.extend(sw_audit.evaluate(engine_report=sw_rep))

    ns_audit, ns_eng, ns_wall, ns_tokens = tight_run(swap=False)
    ns_rep = ns_eng.report()

    for name, toks in (("swap", sw_tokens), ("no-swap", ns_tokens)):
        if not bool((toks == ref_tokens).all()):
            findings.append({
                "severity": "error", "kind": "tiering-exactness",
                "detail": f"{name} constrained run diverged from the "
                          f"uninterrupted reference streams — preemption "
                          f"must never change the answer"})

    # the trace must actually exercise the tier, or the bands attest air
    if sw_rep["preemptions"] == 0 or sw_rep["swap_ins"] == 0 \
            or sw_rep["restored_tokens"] == 0:
        findings.append({
            "severity": "error", "kind": "tiering-no-swap-activity",
            "detail": f"swap run shows no tier activity (preemptions="
                      f"{sw_rep['preemptions']} swap_ins="
                      f"{sw_rep['swap_ins']} restored_tokens="
                      f"{sw_rep['restored_tokens']}): the trace no longer "
                      f"triggers preemption"})

    sw_tokens_out = sum((r >= 0).sum() for r in sw_tokens)
    sw_tps = float(sw_tokens_out) / max(sw_wall, 1e-9)
    ns_tps = float(sw_tokens_out) / max(ns_wall, 1e-9)

    # ---- persisted perf ledger: deterministic tiering counters carry
    # tight bands (they only move when the pathway itself changes);
    # wall-clock throughput is recorded ungated
    ledger_out = None
    if ledger_dir is not None:
        bench_key = f"serve_tiering_{'smoke' if smoke else 'full'}"
        res = Ledger(ledger_dir).compare(
            bench_key,
            {**paged_counter_metrics(sw_rep),
             "swap_restore_rate": float(sw_rep["swap_restore_rate"]),
             "recompute_tokens_saved":
                 float(sw_rep["recompute_tokens_saved"]),
             "preemptions": float(sw_rep["preemptions"]),
             "noswap_extra_decode_steps":
                 float(ns_rep["decode_steps"] - sw_rep["decode_steps"]),
             "swap_tokens_per_s": round(sw_tps, 1),
             "noswap_tokens_per_s": round(ns_tps, 1)},
            PAGED_COUNTER_SPECS
            + [MetricSpec("swap_restore_rate", higher_is_better=True,
                          rel_tol=0.0),
               MetricSpec("recompute_tokens_saved", higher_is_better=True,
                          rel_tol=0.0),
               MetricSpec("preemptions", higher_is_better=False,
                          rel_tol=0.0),
               MetricSpec("noswap_extra_decode_steps",
                          higher_is_better=True, rel_tol=0.0),
               MetricSpec("swap_tokens_per_s", gate=False),
               MetricSpec("noswap_tokens_per_s", gate=False)],
            update_baseline=update_baseline)
        findings.extend(res.findings)
        ledger_out = {"baseline_written": res.baseline_written,
                      "deltas": res.deltas}

    return {
        "bench": "serve_tiering",
        "arch": cfg.name,
        "mode": "smoke" if smoke else "full",
        "oracle_ok": all(oracle_ok.values()),
        "oracle_modes": oracle_ok,
        "trace": {"requests": n_req, "low_max_new": low_max_new,
                  "slots": slots, "num_blocks": blocks,
                  "block_size": block, "chunk": chunk},
        "exact_vs_reference": bool((sw_tokens == ref_tokens).all()
                                   and (ns_tokens == ref_tokens).all()),
        "swap": {
            "preemptions": sw_rep["preemptions"],
            "swap_outs": sw_rep["swap_outs"],
            "swap_ins": sw_rep["swap_ins"],
            "swap_restore_rate": sw_rep["swap_restore_rate"],
            "restored_tokens": sw_rep["restored_tokens"],
            "recompute_tokens": sw_rep["recompute_tokens"],
            "decode_steps": sw_rep["decode_steps"],
            "host_page_peak": sw_rep["host_page_peak"],
            "tokens_per_s": round(sw_tps, 1),
        },
        "no_swap": {
            "preemptions": ns_rep["preemptions"],
            "recompute_tokens": ns_rep["recompute_tokens"],
            "decode_steps": ns_rep["decode_steps"],
            "tokens_per_s": round(ns_tps, 1),
        },
        "recompute_tokens_saved": sw_rep["recompute_tokens_saved"],
        "ledger": ledger_out,
        "findings": findings,
    }


def run():
    """benchmarks.run CSV protocol."""
    res = bench(smoke=True)
    yield {"name": "serve_tiering.swap_vs_recompute",
           "us_per_call": 1e6 / max(res["swap"]["tokens_per_s"], 1e-9),
           "derived": (f"restore_rate={res['swap']['swap_restore_rate']} "
                       f"saved={res['recompute_tokens_saved']} "
                       f"exact={res['exact_vs_reference']} "
                       f"oracle_ok={res['oracle_ok']}")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace sized for a ~2s measured run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ledger-dir", default=None,
                    help="BENCH_*.json directory; omit to skip the ledger")
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args()
    # one JSON object on the last line (the repo's benchmark convention)
    print(json.dumps(bench(args.arch, smoke=args.smoke, seed=args.seed,
                           ledger_dir=args.ledger_dir,
                           update_baseline=args.update_baseline)))


if __name__ == "__main__":
    main()
