"""Serving throughput: paged engine vs contiguous oracle + arrival sweep.

The paper's dual-environment method applied to the serving subsystem:
the same shared-prefix trace runs under both engines; the *numeric*
verdict (identical greedy token streams, via repro.serve.compare_engines)
is the correctness gate, and the throughput ratio is the perf trajectory
metric this PR establishes (paged must clear 1.3x on shared-prefix work —
it skips recomputing cached prefixes and prefills in chunks instead of
one full-batch decode call per prompt token).

    PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke]

Prints one JSON object on the last line.  ``findings`` carries
machine-checkable diagnostics records: scripts/smoke_all.py folds them
into core.diagnostics.Diagnostics and gates CI on errors.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.audit import MetricSpec  # noqa: E402

SPEEDUP_FLOOR = 1.3

#: Deterministic pathway counters from ``PagedServeEngine.report()`` —
#: shared by every serving benchmark's ledger so the gates cannot drift
#: apart.  These only move when the code path itself changes, hence the
#: tight bands; wall-clock metrics are each benchmark's own, ungated.
PAGED_COUNTER_SPECS = [
    MetricSpec("decode_steps", higher_is_better=False, rel_tol=0.05),
    MetricSpec("cached_tokens", higher_is_better=True, rel_tol=0.05),
    MetricSpec("prefix_hit_rate", higher_is_better=True, rel_tol=0.05),
    MetricSpec("tokens_out", higher_is_better=True, rel_tol=0.0),
]


def paged_counter_metrics(rep: dict) -> dict:
    """The ledger metrics matching ``PAGED_COUNTER_SPECS``."""
    return {
        "decode_steps": float(rep["decode_steps"]),
        "cached_tokens": float(rep["cached_tokens"]),
        "prefix_hit_rate": float(rep["prefix_hit_rate"]),
        "tokens_out": float(rep["tokens_out"]),
    }


def _trace_factory(vocab: int, *, n_requests: int, shared_len: int,
                   tail_lo: int, tail_hi: int, max_new: int, seed: int):
    """Deterministic shared-prefix trace: every call returns fresh Request
    objects (engines mutate them) over the same prompts."""
    from repro.serve.engine import Request

    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=shared_len).tolist()
    tails = [rng.integers(0, vocab,
                          size=int(rng.integers(tail_lo, tail_hi + 1))
                          ).tolist()
             for _ in range(n_requests)]

    def make() -> list:
        return [Request(rid=i, prompt=prefix + tails[i], max_new=max_new)
                for i in range(n_requests)]

    return make


def _timed_run(eng, reqs, arrivals=None) -> tuple[float, int, list]:
    """Submit + drain through the unified lifecycle API (both engines
    implement the serve.api.Engine protocol, so one call shape covers
    the contiguous oracle and the paged path)."""
    t0 = time.perf_counter()
    for i, req in enumerate(reqs):
        eng.submit(req, arrival=arrivals[i] if arrivals is not None else None)
    done = eng.drain()
    wall = time.perf_counter() - t0
    return wall, sum(len(r.out) for r in done), done


def bench(arch: str = "deepseek-7b", *, smoke: bool = False,
          seed: int = 0, ledger_dir: str | None = None,
          update_baseline: bool = False) -> dict:
    from repro.audit import AuditContext, Ledger, RunAudit
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import (PagedServeEngine, ServeEngine,
                                    compare_engines, token_matrix)

    if smoke:
        n_req, shared, tails, max_new = 6, 16, (3, 6), 4
        slots, max_len, block, chunk = 2, 48, 8, 4
        rates: list[float] = [2.0]
    else:
        n_req, shared, tails, max_new = 16, 48, (4, 12), 12
        slots, max_len, block, chunk = 4, 128, 8, 8
        rates = [0.25, 0.5, 1.0, 2.0]

    cfg = reduced(ALL_ARCHS[arch])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    make = _trace_factory(cfg.vocab_size, n_requests=n_req,
                          shared_len=shared, tail_lo=tails[0],
                          tail_hi=tails[1], max_new=max_new, seed=seed)
    # same seed => same shared prefix as the measured trace, so warming
    # really does prime the prefix cache (compile warm-up + steady state)
    warm = _trace_factory(cfg.vocab_size, n_requests=slots,
                          shared_len=shared, tail_lo=tails[0],
                          tail_hi=tails[1], max_new=2, seed=seed)
    findings: list[dict] = []

    # -------- correctness first: paged must match the contiguous oracle
    verify = compare_engines(model, params, make, slots=slots,
                             max_len=max_len, block_size=block, chunk=chunk)
    for v in verify.verdicts:
        if not v.ok:
            findings.append({"severity": "error",
                             "kind": f"serve-oracle-{v.kind}",
                             "detail": v.detail})

    # -------- throughput: warm each engine (compile), then time the trace
    contig = ServeEngine(model, params, slots=slots, max_len=max_len)
    contig.run(warm())
    contig_wall, contig_tokens, _ = _timed_run(contig, make())

    audit = RunAudit(AuditContext(workload="bench:serve_throughput",
                                  family=cfg.family, arch=cfg.name,
                                  shared_prefix=True))
    paged = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                             block_size=block, chunk=chunk,
                             tracer=audit.tracer)
    paged.run(warm())   # also primes the prefix cache: steady-state serving
    paged_wall, paged_tokens, paged_done = _timed_run(paged, make())

    # pathway expectations over the measured run's trace + report: the
    # oracle above proves the answer, this proves the route taken
    findings.extend(audit.evaluate(engine_report=paged.report()))

    contig_tps = contig_tokens / max(contig_wall, 1e-9)
    paged_tps = paged_tokens / max(paged_wall, 1e-9)
    speedup = paged_tps / max(contig_tps, 1e-9)
    if speedup < SPEEDUP_FLOOR:
        findings.append({
            "severity": "warn" if smoke else "error",
            "kind": "serve-throughput-regression",
            "detail": f"paged/contiguous speedup {speedup:.2f}x "
                      f"below {SPEEDUP_FLOOR}x floor"})

    # -------- kernel vs gather: the page-table pathway against the dense
    # working-cache fallback on the same paged engine.  Parity is a
    # deterministic gate (the two modes must emit identical streams);
    # the speedup is a tracked wall-clock trajectory metric, ungated —
    # off-accelerator the kernel mode's win is eliminating the admission
    # gather, not the attention kernel itself.
    from repro.kernels import ops as kops

    gather = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                              block_size=block, chunk=chunk,
                              kernel="gather")
    gather.run(warm())
    gather_wall, gather_tokens, gather_done = _timed_run(gather, make())
    gather_tps = gather_tokens / max(gather_wall, 1e-9)
    kernel_vs_gather = paged_tps / max(gather_tps, 1e-9)
    max_new_all = max(r.max_new for r in paged_done)
    kernel_parity = bool(
        (token_matrix(paged_done, n_req, max_new_all)
         == token_matrix(gather_done, n_req, max_new_all)).all())
    # exact stream equality is only guaranteed where both modes lower the
    # same full-softmax math (off-accelerator, via paged_attention_ref);
    # on TPU the Pallas kernel's online-softmax accumulation is
    # tolerance-verified by the kernel-parity suite instead, so a
    # mismatch there is a warning and the ledger metric records ungated
    parity_exact = not kops.use_paged_kernel()
    if not kernel_parity:
        findings.append({
            "severity": "error" if parity_exact else "warn",
            "kind": "serve-kernel-parity",
            "detail": "paged kernel mode and gather fallback emitted "
                      "different token streams on the same trace"})

    # -------- arrival-rate sweep on the paged path (synthetic tick clock)
    sweep = []
    for rate in rates:
        eng = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                               block_size=block, chunk=chunk)
        eng.run(warm())
        # the warm run advanced the tick clock and logged its own TTFTs;
        # rewind so the sweep's arrival offsets mean what they say
        eng.now = 0.0
        eng.ttft_ticks.clear()
        reqs = make()
        arrivals = [i / rate for i in range(len(reqs))]
        wall, tokens, _ = _timed_run(eng, reqs, arrivals)
        rep = eng.report()
        sweep.append({
            "arrival_rate_per_tick": rate,
            "tokens_per_s": round(tokens / max(wall, 1e-9), 1),
            "mean_ttft_ticks": round(float(np.mean(eng.ttft_ticks)), 2)
            if eng.ttft_ticks else None,
            "mean_batch_occupancy": rep["mean_batch_occupancy"],
            "prefix_hit_rate": rep["prefix_hit_rate"],
            "page_peak_utilization": rep["page_peak_utilization"],
        })

    # ---- persisted perf ledger (opt-in via --ledger-dir): deterministic
    # pathway counters carry tight bands; wall-clock throughput is
    # recorded ungated so the trajectory is tracked without CI noise
    ledger_out = None
    if ledger_dir is not None:
        bench_key = f"serve_throughput_{'smoke' if smoke else 'full'}"
        res = Ledger(ledger_dir).compare(
            bench_key,
            {**paged_counter_metrics(paged.report()),
             "paged_tokens_per_s": round(paged_tps, 1),
             "speedup": round(speedup, 2),
             # kernel parity is a deterministic counter (1.0 = streams
             # identical) gated zero-tolerance where both modes lower
             # the same math (off-accelerator); the kernel-vs-gather
             # speedup is wall clock, tracked ungated
             "kernel_parity": 1.0 if kernel_parity else 0.0,
             "kernel_vs_gather_speedup": round(kernel_vs_gather, 2)},
            PAGED_COUNTER_SPECS
            + [MetricSpec("paged_tokens_per_s", gate=False),
               MetricSpec("speedup", gate=False),
               MetricSpec("kernel_parity", higher_is_better=True,
                          rel_tol=0.0, gate=parity_exact),
               MetricSpec("kernel_vs_gather_speedup", gate=False)],
            update_baseline=update_baseline)
        findings.extend(res.findings)
        ledger_out = {"baseline_written": res.baseline_written,
                      "deltas": res.deltas}

    return {
        "bench": "serve_throughput",
        "arch": cfg.name,
        "mode": "smoke" if smoke else "full",
        "ledger": ledger_out,
        "trace": {"requests": n_req, "shared_prefix": shared,
                  "max_new": max_new, "slots": slots, "chunk": chunk,
                  "block_size": block},
        "contiguous_tokens_per_s": round(contig_tps, 1),
        "paged_tokens_per_s": round(paged_tps, 1),
        "gather_tokens_per_s": round(gather_tps, 1),
        "speedup": round(speedup, 2),
        "kernel_vs_gather_speedup": round(kernel_vs_gather, 2),
        "kernel_parity_ok": kernel_parity,
        "oracle_ok": verify.ok,
        "paged": paged.report(),
        "arrival_sweep": sweep,
        "findings": findings,
    }


def run():
    """benchmarks.run CSV protocol."""
    res = bench(smoke=True)
    yield {"name": "serve_throughput.paged_vs_contig",
           "us_per_call": 1e6 / max(res["paged_tokens_per_s"], 1e-9),
           "derived": (f"speedup={res['speedup']}x "
                       f"oracle_ok={res['oracle_ok']} "
                       f"kernel_parity={res['kernel_parity_ok']} "
                       f"hit_rate={res['paged']['prefix_hit_rate']}")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace sized for a ~2s measured run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ledger-dir", default=None,
                    help="BENCH_*.json directory; omit to skip the ledger")
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args()
    # one JSON object on the last line (the repo's benchmark convention)
    print(json.dumps(bench(args.arch, smoke=args.smoke, seed=args.seed,
                           ledger_dir=args.ledger_dir,
                           update_baseline=args.update_baseline)))


if __name__ == "__main__":
    main()
