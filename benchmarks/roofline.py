"""§Roofline: the three-term analysis per (arch × shape × mesh) from the
dry-run's compiled artifacts (launch/dryrun.py JSON records).

  compute    = HLO_dot_flops(per-device, loop-trip-weighted) / peak_FLOP/s
  memory     = HLO_bytes(per-device, fusion-optimistic model) / HBM_bw
  collective = moved_bytes(per-device, ring model)          / ICI link bw

Sources: inspector.hlo_cost (XLA's own cost_analysis counts while bodies
once — see inspector docstring) and inspector.parse_hlo.  The dominant term
is the bottleneck; MODEL_FLOPS/HLO_FLOPs shows how much compiled compute is
"useful" (remat + causal-mask waste + padding appear here).  Writes
EXPERIMENTS/roofline.csv + .md.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks._util import HBM_BW, ICI_BW, PEAK_FLOPS

DRYRUN_DIR = Path("EXPERIMENTS/dryrun")

# assignment-table attention geometry needed for the analytic score-traffic
# estimate (kept minimal: heads after run-padding at tp=16)
_ATTN = {  # arch -> (n_layers_with_self_attn, H_run@tp16)
    "llama-3.2-vision-11b": (40, 32), "phi3-mini-3.8b": (32, 32),
    "phi3-medium-14b": (40, 80), "deepseek-7b": (30, 32),
    "deepseek-coder-33b": (62, 64), "qwen3-moe-30b-a3b": (48, 32),
    "granite-moe-1b-a400m": (24, 16), "whisper-medium": (48, 16),
    "zamba2-2.7b": (9, 32),
}


def attn_score_bytes_per_dev(rec: dict) -> float:
    """HBM traffic of materialized attention scores the flash kernel keeps
    in VMEM: per layer per pass, write+read of fp32 scores + probs
    ~ 3 · B·H·S² · 4B, sharded over all devices; train runs 3 passes
    (fwd, remat-fwd, bwd), prefill 1."""
    arch = rec["arch"]
    if arch not in _ATTN:
        return 0.0
    n_dev = 512 if rec["mesh"] == "2x16x16" else 256
    layers, h_run = _ATTN[arch]
    shape = rec["shape"]
    if shape == "train_4k":
        b, s, passes = 256, 4096, 3
    elif shape == "prefill_32k":
        b, s, passes = 32, 32768, 1
    else:
        return 0.0
    mb = max(rec.get("microbatches", 0), 1)
    # causal: ~S²/2 scored pairs; 3 array traversals (write scores, read
    # for softmax-normalized probs, read probs for the AV matmul)
    total = passes * layers * 3.0 * b * h_run * (s * s / 2) * 4.0
    return total / n_dev


def model_flops(rec: dict) -> float:
    """6·N·D total (N = active non-embedding params; D = tokens processed).
    train counts fwd+bwd (6ND); prefill/decode fwd only (2ND)."""
    n = rec["params_nonembed_active"]
    shape = rec["shape"]
    if shape == "train_4k":
        tokens, factor = 256 * 4096, 6.0
    elif shape == "prefill_32k":
        tokens, factor = 32 * 32768, 2.0
    elif shape == "decode_32k":
        tokens, factor = 128 * 1, 2.0
    else:  # long_500k decode
        tokens, factor = 1 * 1, 2.0
    return factor * n * tokens


def analyze(rec: dict) -> dict:
    n_dev = 512 if rec["mesh"] == "2x16x16" else 256
    hc = rec["hlo_cost"]
    coll = rec["collectives"]["total_moved_bytes"]
    coll_adj = rec["collectives"].get("tpu_adjusted_moved_bytes", coll)
    t_c = hc["dot_flops"] / PEAK_FLOPS
    t_m = hc["bytes"] / HBM_BW
    t_x = coll / ICI_BW
    t_x_adj = coll_adj / ICI_BW  # f32 promotion on XLA:CPU halved (inspector)
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_x_adj), key=lambda kv: kv[1])
    mf = model_flops(rec)
    useful = mf / n_dev / max(hc["dot_flops"], 1e-9)
    step_time = max(t_c, t_m, t_x_adj)  # no-overlap bound on the max term
    mfu = (mf / n_dev / max(step_time, 1e-12)) / PEAK_FLOPS
    mfu_raw = (mf / n_dev / max(max(t_c, t_m, t_x), 1e-12)) / PEAK_FLOPS
    # what the flash kernel buys: score blocks stay in VMEM
    t_m_kernel = max(hc["bytes"] - attn_score_bytes_per_dev(rec), 0) / HBM_BW
    mfu_kernel = (mf / n_dev / max(max(t_c, t_m_kernel, t_x_adj), 1e-12)) / PEAK_FLOPS
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "rules": rec.get("rules", "auto"), "microbatches": rec.get("microbatches", 0),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "collective_adj_s": t_x_adj,
        "dominant": dominant[0],
        "model_flops_total": mf,
        "useful_ratio": useful,
        "roofline_mfu": mfu,
        "roofline_mfu_raw": mfu_raw,
        "memory_kernel_s": t_m_kernel,
        "roofline_mfu_kernel": mfu_kernel,
        "mem_gib": rec.get("memory", {}).get("per_device_total", 0) / 2**30,
        "fits_hbm": rec.get("memory", {}).get("per_device_total", 0) <= 16 * 2**30,
    }


def improvement_note(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("restructure block-boundary reductions (reduce-scatter in "
                "place of the fp32 all-reduce GSPMD emits) / overlap "
                "gathers with the scan body")
    if d == "memory":
        return ("flash/SSD kernels keep score blocks in VMEM; shrink "
                "saved-activation stack (more microbatches or offload)")
    return "raise arithmetic intensity: bigger per-device tiles, less remat"


def load_records(pattern: str = "*.json") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(str(DRYRUN_DIR / pattern))):
        rec = json.loads(Path(f).read_text())
        if rec.get("status") == "ok":
            recs.append(rec)
    return recs


def write_tables(rows: list[dict]) -> None:
    out = Path("EXPERIMENTS")
    out.mkdir(exist_ok=True)
    cols = ["arch", "shape", "mesh", "rules", "microbatches", "compute_s",
            "memory_s", "memory_kernel_s", "collective_s",
            "collective_adj_s", "dominant", "useful_ratio", "roofline_mfu",
            "roofline_mfu_raw", "roofline_mfu_kernel", "mem_gib",
            "fits_hbm"]
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols))
    (out / "roofline.csv").write_text("\n".join(lines) + "\n")

    md = ["| arch | shape | mesh | compute s | memory s | collective s "
          "(tpu-adj) | dominant | useful | MFU bound | mem GiB | fits |",
          "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} ({r['collective_adj_s']:.3f}) "
            f"| **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_mfu']:.1%} "
            f"| {r['mem_gib']:.1f} | {'y' if r['fits_hbm'] else 'NO'} |")
    (out / "roofline.md").write_text("\n".join(md) + "\n")


def run() -> list[dict]:
    recs = load_records()
    rows = [analyze(r) for r in recs]
    write_tables(rows)
    out = []
    for r in rows:
        out.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            "us_per_call": max(r["compute_s"], r["memory_s"],
                               r["collective_s"]) * 1e6,
            "derived": (f"dom={r['dominant']};mfu_bound={r['roofline_mfu']:.3f};"
                        f"useful={r['useful_ratio']:.2f};"
                        f"fits={'y' if r['fits_hbm'] else 'n'}"),
        })
    return out
