"""Cluster scaling benchmark: replica sweep under routing verification.

The single-host benchmarks prove the paged engine takes the optimal
pathway; this one climbs a layer and judges the *cluster router*
(``repro.serve.cluster``) the same way, in the scaling-verification
discipline of the EBRAINS container study (OSU/NCCL-style ``r_max``):

Per PR 5 workload family (multi-tenant chat, RAG, agent loops):

  1. ``compare_engines`` cluster mode — ``ClusterEngine(n=1)`` and
     ``ClusterEngine(n=3)`` must be token-exact against the single paged
     engine, greedy AND sampled (counter-based sampling is placement-
     independent, so ANY routing that preserves requests whole must
     reproduce the single-engine streams bit for bit);
  2. a replica sweep (n = 1, 2, 3) of metered affinity-routed runs over
     the family's trace with its arrival ticks — each replica's tracer
     feeds a replica-labelled ``ServeMetrics`` into one shared registry
     behind one ``MetricsServer`` (the aggregation ``launch.serve
     --replicas`` exposes over HTTP);
  3. scaling + routing judgement on deterministic tick-clock counters:
     ``scaling_rmax`` (peak tokens-per-tick across the sweep, r_max in
     the OSU sense), ``routed_affinity`` (fraction of affinity
     opportunities the router converted) and ``shared_hit_rate``
     (cluster-wide prefix reuse) at n=3, all ledgered into
     ``BENCH_serve_cluster_smoke.json`` with tight bands; wall-clock
     throughput rides along ungated (trajectory only).

    PYTHONPATH=src python benchmarks/serve_cluster.py [--smoke]
        [--ledger-dir DIR] [--update-baseline]

Prints one JSON object on the last line; ``findings`` carries the
diagnostics records scripts/smoke_all.py folds into the CI gate.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402

#: Replica counts swept per family (n=1 doubles as the degenerate-router
#: sanity point: one replica, affinity vacuously perfect).
REPLICA_SWEEP = (1, 2, 3)

#: Replica counts held to the token-identity oracle, greedy and sampled.
ORACLE_REPLICAS = (1, 3)

#: Per-replica engine geometry (every replica runs the serve_workloads
#: geometry, so per-replica capacity is constant and the sweep scales
#: total capacity linearly).
GEOMETRY = {"slots": 3, "max_len": 64, "block_size": 8, "chunk": 4}


def _ctx(cfg):
    from repro.audit import AuditContext

    return AuditContext(workload="bench:serve_cluster", family=cfg.family,
                        arch=cfg.name, shared_prefix=True)


def bench(arch: str = "deepseek-7b", *, smoke: bool = True, seed: int = 0,
          ledger_dir: str | None = None,
          update_baseline: bool = False) -> dict:
    from repro.audit import (EventLog, Ledger, MetricSpec, MetricsRegistry,
                             MetricsServer, RunAudit, ServeMetrics, Tracer)
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve import (ClusterEngine, SamplingParams, compare_engines,
                             generate, smoke_specs)

    mode = "smoke" if smoke else "full"
    cfg = reduced(ALL_ARCHS[arch])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    specs = smoke_specs(vocab_size=cfg.vocab_size, seed=seed)
    g = GEOMETRY
    sampled = SamplingParams(temperature=0.8, top_k=20, top_p=0.95,
                             seed=seed + 1)

    findings: list[dict] = []
    families = []
    ledger_metrics: dict[str, float] = {}
    rmaxes, affinities, shared_hits = [], [], []

    for spec in specs:
        trace = generate(spec)
        assert trace.max_feed <= g["max_len"], (spec.name, trace.max_feed)

        # ---- 1. routing oracle: the cluster must reproduce the single
        # paged engine's streams exactly, at n=1 and n=3, greedy & sampled
        oracle_ok = True
        for n in ORACLE_REPLICAS:
            for sname, sp in (("greedy", None), ("sampled", sampled)):
                verify = compare_engines(
                    model, params, trace.requests, slots=g["slots"],
                    max_len=g["max_len"], block_size=g["block_size"],
                    chunk=g["chunk"], sampling=sp,
                    cluster={"replicas": n})
                oracle_ok = oracle_ok and verify.ok
                for v in verify.verdicts:
                    if not v.ok:
                        findings.append({
                            "severity": "error",
                            "kind": f"cluster-oracle-{spec.name}-n{n}-{sname}",
                            "detail": v.detail})

        # ---- 2. replica sweep: metered affinity-routed runs, one shared
        # metrics registry with replica-labelled series per run
        sweep = []
        fam_rmax = 0.0
        fam_affinity = fam_shared = None
        for n in REPLICA_SWEEP:
            audit = RunAudit(_ctx(cfg))
            registry = MetricsRegistry()
            log = EventLog()
            audit.tracer.subscribe(log.append)
            cluster_metrics = ServeMetrics(registry)    # router's own view
            cluster_metrics.attach(audit.tracer)
            replica_tracers = [Tracer() for _ in range(n)]
            replica_metrics = []
            for i, rt in enumerate(replica_tracers):
                sm = ServeMetrics(registry, labels={"replica": str(i)})
                sm.attach(rt)
                replica_metrics.append(sm)
            eng = ClusterEngine(model, params, replicas=n,
                                slots=g["slots"], max_len=g["max_len"],
                                block_size=g["block_size"], chunk=g["chunk"],
                                routing="affinity", tracer=audit.tracer,
                                replica_tracers=replica_tracers)
            t0 = time.perf_counter()
            eng.run(trace.requests(), arrivals=trace.arrivals)
            wall = time.perf_counter() - t0
            rep = eng.report()

            fam_findings = audit.evaluate(engine_report=rep)
            findings.extend(fam_findings)

            # deterministic throughput: tokens per cluster tick (the
            # synthetic clock advances 1.0/step, so eng.now is the tick
            # count and the rate is a pure function of the trace)
            tpt = rep["tokens_out"] / max(eng.now, 1.0)
            fam_rmax = max(fam_rmax, tpt)
            if n == max(REPLICA_SWEEP):
                fam_affinity = rep["routed_affinity"]
                fam_shared = rep["shared_hit_rate"]

            # the exposition layer is part of the measured pathway:
            # replica-labelled series render through one endpoint
            server = MetricsServer(registry, log)
            _, _, prom = server.handle("/metrics")
            text = prom.decode()
            labelled_ok = (n == 1 or
                           all(f'replica="{i}"' in text for i in range(n)))
            if not labelled_ok:
                findings.append({
                    "severity": "error", "kind": "cluster-metrics-labels",
                    "detail": f"{spec.name} n={n}: replica-labelled series "
                              f"missing from the shared exposition"})
            sweep.append({
                "replicas": n,
                "tokens_per_tick": round(tpt, 3),
                "tokens_per_s": round(rep["tokens_out"] / max(wall, 1e-9), 1),
                "ticks": eng.now,
                "routed_affinity": rep["routed_affinity"],
                "shared_hit_rate": rep["shared_hit_rate"],
                "routed": rep["routed"],
                "spills": rep["routed_spills"],
                "preemptions": rep["preemptions"],
                "summary_rebuilds": rep["summary_rebuilds"],
                "prometheus_sha256": hashlib.sha256(prom).hexdigest(),
                "events_logged": len(log),
                "route_events": audit.tracer.count("route"),
            })

        rmaxes.append(fam_rmax)
        affinities.append(fam_affinity)
        shared_hits.append(fam_shared)
        key = spec.name.replace("-", "_")
        ledger_metrics[f"{key}_scaling_rmax"] = round(fam_rmax, 3)
        ledger_metrics[f"{key}_routed_affinity"] = float(fam_affinity)
        ledger_metrics[f"{key}_shared_hit_rate"] = float(fam_shared)
        families.append({
            "workload": trace.describe(),
            "oracle_ok": oracle_ok,
            "scaling_rmax": round(fam_rmax, 3),
            "sweep": sweep,
        })

    # aggregate headline metrics (mean across families; rmax already a
    # max across the sweep within each family)
    agg = {
        "scaling_rmax": round(sum(rmaxes) / len(rmaxes), 3),
        "routed_affinity": round(sum(affinities) / len(affinities), 3),
        "shared_hit_rate": round(sum(shared_hits) / len(shared_hits), 3),
    }
    ledger_metrics.update(agg)

    # ---- ledger: deterministic tick-clock metrics gated tight; the
    # routing ratios are exact functions of the traces (rel_tol 0.05
    # absorbs only rounding), rmax gets 0.1 headroom for scheduler
    # changes that legitimately shift tick counts
    ledger_out = None
    if ledger_dir is not None:
        ledger = Ledger(ledger_dir)
        specs_l = []
        for name in ledger_metrics:
            if name.endswith("_scaling_rmax") or name == "scaling_rmax":
                specs_l.append(MetricSpec(name, higher_is_better=True,
                                          rel_tol=0.1))
            else:
                specs_l.append(MetricSpec(name, higher_is_better=True,
                                          rel_tol=0.05))
        bench_key = f"serve_cluster_{mode}"
        res = ledger.compare(bench_key, ledger_metrics, specs_l,
                             update_baseline=update_baseline)
        findings.extend(res.findings)
        ledger_out = {"baseline_written": res.baseline_written,
                      "deltas": res.deltas,
                      "path": str(ledger.path(bench_key))}

    return {
        "bench": "serve_cluster",
        "arch": cfg.name,
        "mode": mode,
        "replica_sweep": list(REPLICA_SWEEP),
        "oracle_ok": all(f["oracle_ok"] for f in families),
        **agg,
        "families": families,
        "ledger": ledger_out,
        "findings": findings,
    }


def run():
    """benchmarks.run CSV protocol."""
    res = bench(smoke=True)
    n_err = sum(1 for f in res["findings"] if f["severity"] == "error")
    if n_err:
        raise RuntimeError(f"serve_cluster: {n_err} error finding(s): "
                           + "; ".join(f["detail"] for f in res["findings"]
                                       if f["severity"] == "error"))
    for fam in res["families"]:
        peak = max(fam["sweep"], key=lambda s: s["tokens_per_tick"])
        yield {"name": f"serve_cluster.{fam['workload']['workload']}",
               "us_per_call": 1e6 / max(peak["tokens_per_s"], 1e-9),
               "derived": (f"rmax={fam['scaling_rmax']} "
                           f"affinity={peak['routed_affinity']} "
                           f"shared_hit={peak['shared_hit_rate']} "
                           f"oracle_ok={fam['oracle_ok']}")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ledger-dir", default=None,
                    help="BENCH_*.json directory; omit to skip the ledger")
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args()
    print(json.dumps(bench(args.arch, smoke=args.smoke, seed=args.seed,
                           ledger_dir=args.ledger_dir,
                           update_baseline=args.update_baseline)))


if __name__ == "__main__":
    main()
