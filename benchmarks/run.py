"""Benchmark driver — one module per paper table/figure (DESIGN.md §10).
Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only roofline,osu_init,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

SUITES = [
    ("roofline", "benchmarks.roofline"),          # §Roofline (from dry-run)
    ("osu_init", "benchmarks.osu_init"),          # Fig 1
    ("osu_latency", "benchmarks.osu_latency"),    # Figs 2/3
    ("allreduce_bw", "benchmarks.allreduce_bw"),  # Figs 4/5
    ("ring_scaling", "benchmarks.ring_scaling"),  # Figs 6/7 + 8/9
    ("ring_accel", "benchmarks.ring_accel"),      # Figs 10/11
    ("ring_podscale", "benchmarks.ring_podscale"),  # Figs 6/7 at paper scale (dry-run)
    ("serve_throughput", "benchmarks.serve_throughput"),  # paged serving
    ("audit_pathways", "benchmarks.audit_pathways"),  # runtime audit gate
    ("serve_workloads", "benchmarks.serve_workloads"),  # workload-family SLOs
    ("serve_cluster", "benchmarks.serve_cluster"),  # replica scaling + routing
    ("serve_tiering", "benchmarks.serve_tiering"),  # KV swap tier vs recompute
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--all", action="store_true",
                    help="run every registered suite (the default; spelled "
                         "out so CI invocations are explicit)")
    args = ap.parse_args()
    if args.all and args.only:
        ap.error("--all and --only are mutually exclusive")
    only = set(args.only.split(",")) if args.only else None
    known = {name for name, _ in SUITES}
    if only and not only <= known:
        import difflib

        unknown = []
        for bad in sorted(only - known):
            close = difflib.get_close_matches(bad, sorted(known), n=1)
            unknown.append(f"{bad!r} (did you mean {close[0]!r}?)"
                           if close else repr(bad))
        ap.error(f"unknown suite(s): {', '.join(unknown)}; "
                 f"registered: {sorted(known)}")

    print("name,us_per_call,derived")
    failures = 0
    for name, module in SUITES:
        if only and name not in only:
            continue
        try:
            import importlib

            mod = importlib.import_module(module)
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.2f},{derived}")
                sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} suite(s) failed")


if __name__ == "__main__":
    main()
