"""Paper Figs. 4/5 (NCCL all_reduce_perf): AllReduce bus bandwidth by
message size, single-node vs two-node.

TPU analogue: psum over the mesh.  busbw = 2(n-1)/n · size / t (the NCCL
convention).  Measured on the 8-device in-process mesh (single-pod
analogue); derived models the cross-pod case where the pod axis adds a
2-hop DCN-ish link at pod bandwidth — the paper's ≈2× NIC-topology gap
appears as the single/multi-pod ratio.
"""
from __future__ import annotations

from benchmarks._util import ICI_BW, run_devices

SIZES = [1024, 64 * 1024, 1024 * 1024, 16 * 1024 * 1024, 64 * 1024 * 1024]

CODE = """
import json, time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
n_dev = 8
mesh = jax.make_mesh((n_dev,), ("x",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rows = {{}}
for size in {sizes}:
    n = max(size // 4, n_dev)
    x = jnp.ones((n_dev, n // n_dev), jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("x")))
    def f(v):
        s = jnp.broadcast_to(v.sum(axis=0, keepdims=True), v.shape)
        return jax.lax.with_sharding_constraint(
            s, NamedSharding(mesh, P("x")))
    fn = jax.jit(f)
    fn(xs).block_until_ready()
    times = []
    for _ in range(8):
        t0 = time.perf_counter()
        fn(xs).block_until_ready()
        times.append(time.perf_counter() - t0)
    rows[str(size)] = min(times)
print(json.dumps(rows))
"""


def run() -> list[dict]:
    out = run_devices(CODE.format(sizes=SIZES), 8)
    rows = []
    n = 8
    for size in SIZES:
        t = out[str(size)]
        busbw = 2 * (n - 1) / n * size / t
        # v5e model: ring all-reduce at ICI bw; cross-pod halves the
        # bottleneck link (one pod-to-pod trunk per ring direction)
        t_ici = 2 * (n - 1) / n * size / ICI_BW
        t_xpod = 2 * (n - 1) / n * size / (ICI_BW / 2)
        rows.append({
            "name": f"allreduce_bw/size={size}B/single-pod",
            "us_per_call": t * 1e6,
            "derived": (f"busbw_GBps={busbw / 1e9:.2f};"
                        f" v5e_model_us={t_ici * 1e6:.1f};"
                        f" xpod_model_us={t_xpod * 1e6:.1f}"),
        })
    return rows
