"""Paper Figs. 10/11 (Arbor GPU strong/weak): the accelerated-kernel
environment vs the portable path, with the paper's overhead-classification
analysis.

On real TPU hardware the Pallas HH kernel is the fast path; in this CPU
container it runs in interpret mode, so wall-clock favours the jnp path —
the MEASUREMENT we reproduce is the paper's methodology: run the identical
workload in two environments at several scales, verify numerical identity,
and classify the overhead as constant (per-launch cost, acceptable) vs
scaling (communication penalty, a misconfiguration).  The paper's GPU
container showed a constant 12-19%; our interpret-mode overhead must also
classify as constant for the harness to pass.
"""
from __future__ import annotations

import numpy as np

from repro.core.verify import DualEnvHarness, constant_vs_scaling_overhead
from repro.neuro.cable import CellConfig
from repro.neuro.ring import RingConfig
from repro.neuro.sim import simulate


def run() -> list[dict]:
    rows = []
    overheads = {}
    for cells in (64, 128, 256):
        cfg = RingConfig(n_cells=cells, t_end_ms=10.0,
                         cell=CellConfig(n_compartments=4))
        h = DualEnvHarness(repeats=2, warmup=0)
        rep = h.compare(
            "oracle", lambda cfg=cfg: np.asarray(
                simulate(cfg, use_pallas=False).spike_counts),
            "pallas", lambda cfg=cfg: np.asarray(
                simulate(cfg, use_pallas=True).spike_counts),
            rtol=1e-9, atol=1e-9, timing_band=None)
        assert rep.verdicts[0].ok, "kernel/oracle spike mismatch"
        over = (rep.b.mean - rep.a.mean) / max(rep.a.mean, 1e-9)
        overheads[cells] = over
        rows.append({
            "name": f"ring_accel/cells={cells}/oracle",
            "us_per_call": rep.a.mean * 1e6,
            "derived": f"numeric=identical",
        })
        rows.append({
            "name": f"ring_accel/cells={cells}/pallas-interpret",
            "us_per_call": rep.b.mean * 1e6,
            "derived": f"overhead={over:+.1%}",
        })
    klass = constant_vs_scaling_overhead(overheads)
    rows.append({
        "name": "ring_accel/overhead-classification",
        "us_per_call": 0.0,
        "derived": klass,
    })
    return rows
