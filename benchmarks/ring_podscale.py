"""Arbor's published scale, dry-run: the 128 000-cell ring network lowered
onto a 256-way pod mesh (the workload behind paper Figs 6/7 at the node
counts the paper actually used).  Proves the BSP spike-exchange program
compiles at production scale and reports its exchange traffic — one
all-gather per min-delay epoch, int8 spike flags (§Perf iteration 4:
4× less exchange traffic than f32 flags).
"""
from __future__ import annotations

from benchmarks._util import ICI_BW, run_devices

CODE = """
import json, time
import jax
from repro.neuro.ring import RingConfig
from repro.neuro.cable import CellConfig
from repro.neuro.sim import _run_local, shard_map
from repro.neuro import cable
from repro.core.inspector import parse_hlo

cfg = RingConfig(n_cells=131072, t_end_ms=200.0, delay_ms=5.0,
                 cell=CellConfig(n_compartments=32))
mesh = jax.make_mesh((256,), ("cells",),
                     axis_types=(jax.sharding.AxisType.Auto,))
n_loc = cfg.n_cells // 256
run = _run_local(cfg, n_loc, "cells", False)
spec = jax.sharding.PartitionSpec("cells")
state_specs = cable.CellState(v=spec, m=spec, h=spec, n=spec, g_syn=spec)
fn = shard_map(run, mesh=mesh, in_specs=(state_specs,),
               out_specs=(state_specs, spec, jax.sharding.PartitionSpec()),
               check_vma=False)
f32 = jax.numpy.float32
state_abs = cable.CellState(
    v=jax.ShapeDtypeStruct((cfg.n_cells, 32), f32),
    m=jax.ShapeDtypeStruct((cfg.n_cells,), f32),
    h=jax.ShapeDtypeStruct((cfg.n_cells,), f32),
    n=jax.ShapeDtypeStruct((cfg.n_cells,), f32),
    g_syn=jax.ShapeDtypeStruct((cfg.n_cells,), f32))
t0 = time.time()
compiled = jax.jit(fn).lower(state_abs).compile()
rep = parse_hlo(compiled.as_text(), 256)
m = compiled.memory_analysis()
mem = (m.argument_size_in_bytes + m.temp_size_in_bytes
       + m.output_size_in_bytes - m.alias_size_in_bytes)
print(json.dumps({
    "compile_s": round(time.time() - t0, 2),
    "mem_gib": mem / 2**30,
    "epochs": cfg.n_epochs,
    "moved_bytes": rep.total_moved_bytes,
    "counts": rep.counts(),
}))
"""


def run() -> list[dict]:
    out = run_devices(CODE, 512, timeout=900)
    per_epoch = out["moved_bytes"] / max(out["epochs"], 1)
    return [{
        "name": "ring_podscale/128k-cells/256-way",
        "us_per_call": out["compile_s"] * 1e6,
        "derived": (f"mem_gib={out['mem_gib']:.3f};"
                    f"allgather_per_epoch_MB={per_epoch/2**20:.1f};"
                    f"exchange_model_us={per_epoch/ICI_BW*1e6:.0f};"
                    f"counts={out['counts']}"),
    }]
