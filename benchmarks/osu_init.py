"""Paper Fig. 1 (osu_init): runtime-bootstrap latency vs scale.

MPI_Init's cost structure (PMIx exchange + transport discovery + endpoint
setup) maps to: mesh construction + first-collective compile (cold) vs
steady-state issue (warm).  The dual environments are cold/warm — the same
contrast the paper measures between container (extra namespace work) and
native bootstrap paths.  Measured on in-process device counts 1..8;
`derived` models the 256-chip pod from the per-device slope.
"""
from __future__ import annotations

from benchmarks._util import run_devices

CODE = """
import json, time
import jax
from repro.core.bootstrap import init_benchmark
out = init_benchmark(({n}, 1), ("data", "model"), repeats=3)
print(json.dumps(out))
"""


def run() -> list[dict]:
    rows = []
    base_cold = None
    for n in (1, 2, 4, 8):
        out = run_devices(CODE.format(n=n), n)
        cold = out["mesh_construct_s"] + out["first_collective_s"]
        warm = out["steady_collective_s"]
        if base_cold is None:
            base_cold = cold
        rows.append({
            "name": f"osu_init/devices={n}/cold",
            "us_per_call": cold * 1e6,
            "derived": f"overhead_vs_1dev={cold / base_cold:.2f}x",
        })
        rows.append({
            "name": f"osu_init/devices={n}/warm",
            "us_per_call": warm * 1e6,
            "derived": f"cold_warm_ratio={cold / max(warm, 1e-9):.0f}x",
        })
    return rows
