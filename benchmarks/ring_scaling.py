"""Paper Figs. 6/7 (Arbor ring CPU strong+weak scaling) and Figs. 8/9
(NEURON ringtest strong+weak): the BSP ring simulation across rank counts.

Strong: fixed total cells, ranks 1..8 (subprocess meshes) — paper Fig 6.
Weak: fixed cells/rank — paper Fig 7.
neuron_ringtest: many independent rings (chains), paper Figs 8/9.
Efficiency definitions match the paper (T1/(N·TN) strong; T1/TN weak).
"""
from __future__ import annotations

from benchmarks._util import run_devices

CODE = """
import json
import jax
from repro.neuro.ring import RingConfig
from repro.neuro.cable import CellConfig
from repro.neuro.sim import simulate
cfg = RingConfig(n_cells={cells}, n_rings={rings}, t_end_ms={t_end},
                 cell=CellConfig(n_compartments={comp}))
if {ranks} > 1:
    mesh = jax.make_mesh(({ranks},), ("cells",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    r = simulate(cfg, mesh=mesh)
else:
    r = simulate(cfg)
print(json.dumps({{"wall_s": r.wall_s, "spikes": r.total_spikes}}))
"""


def _sweep(name: str, cells_fn, rings: int, t_end: float,
           comp: int = 8) -> list[dict]:
    rows = []
    t1 = None
    for ranks in (1, 2, 4, 8):
        cells = cells_fn(ranks)
        out = run_devices(
            CODE.format(cells=cells, rings=rings, t_end=t_end, comp=comp,
                        ranks=ranks), ranks)
        wall = out["wall_s"]
        if ranks == 1:
            t1 = wall
        if "strong" in name:
            eff = t1 / (ranks * wall)
        else:
            eff = t1 / wall
        rows.append({
            "name": f"{name}/ranks={ranks}",
            "us_per_call": wall * 1e6,
            "derived": f"cells={cells};spikes={out['spikes']};"
                       f"efficiency={eff:.2f}",
        })
    return rows


def run() -> list[dict]:
    rows = []
    # Fig 6: Arbor ring strong scaling (fixed problem)
    rows += _sweep("arbor_ring/strong", lambda r: 2048, rings=1, t_end=20.0)
    # Fig 7: Arbor ring weak scaling (cells grow with ranks)
    rows += _sweep("arbor_ring/weak", lambda r: 256 * r, rings=1, t_end=20.0)
    # Fig 8: NEURON ringtest strong (many independent rings)
    rows += _sweep("neuron_ringtest/strong", lambda r: 2048, rings=16,
                   t_end=20.0, comp=4)
    # Fig 9: NEURON ringtest weak
    rows += _sweep("neuron_ringtest/weak", lambda r: 256 * r, rings=8,
                   t_end=20.0, comp=4)
    return rows
