"""Paper Figs. 2/3 (osu_latency): point-to-point latency by message size.

p2p on TPU is collective-permute over one ICI hop.  Measured: 2-device
in-process mesh (the intra-node/shared-memory analogue).  Derived: the
v5e ICI model latency (hop latency + size/link bandwidth) — the inter-node
analogue the paper plots alongside.
"""
from __future__ import annotations

from benchmarks._util import ICI_BW, ICI_LAT, run_devices

SIZES = [8, 1024, 16 * 1024, 128 * 1024, 1024 * 1024, 8 * 1024 * 1024]

CODE = """
import json, time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((2,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
rows = {{}}
for size in {sizes}:
    n = max(size // 4, 2)
    x = jnp.zeros((2, n // 2), jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("x")))
    def f(v):
        return jax.lax.with_sharding_constraint(
            jnp.roll(v, 1, axis=0), NamedSharding(mesh, P("x")))
    fn = jax.jit(f)
    fn(xs).block_until_ready()
    times = []
    for _ in range(10):
        t0 = time.perf_counter()
        fn(xs).block_until_ready()
        times.append(time.perf_counter() - t0)
    rows[str(size)] = min(times)
print(json.dumps(rows))
"""


def run() -> list[dict]:
    out = run_devices(CODE.format(sizes=SIZES), 2)
    rows = []
    for size in SIZES:
        measured = out[str(size)]
        model = ICI_LAT + size / ICI_BW
        rows.append({
            "name": f"osu_latency/size={size}B/intra(measured)",
            "us_per_call": measured * 1e6,
            "derived": f"ici_model_us={model * 1e6:.2f}",
        })
    return rows
