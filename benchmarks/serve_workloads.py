"""Workload-family SLO benchmark: generated traces under quantile gates.

serve_throughput proves the paged engine beats the contiguous oracle on
one hand-built shared-prefix trace.  This benchmark widens the evidence
to the *workload families* the serving stack claims to handle — the
``repro.serve.workloads`` generator's multi-tenant chat, RAG, and
agent-loop traces, each under a different arrival process (diurnal,
heavy-tail, bursty) — and judges them the way an operator would: against
latency SLOs.

Per family:

  1. ``compare_engines`` — the dual-environment token-identity verdict
     must stay green on the family's trace (greedy streams, paged vs
     contiguous);
  2. a metered paged run over the trace *with its arrival ticks*, the
     audit tracer feeding a live ``ServeMetrics`` registry through the
     subscription hook (the same pipeline ``launch.serve
     --metrics-port`` exposes over HTTP);
  3. SLO judgement — a calibrated ``ExpectedSignature`` with
     ``p99_ttft_ticks`` / ``p99_decode_gap_ticks`` / ``min_prefix_hit_
     rate`` / ``max_preempted_share`` bounds; breaches surface as
     ``pathway-slo`` / ``pathway-attribution`` error findings.
     All latencies are tick-clock, so the p99s are deterministic and the
     ledger gates them with tight bands; wall-clock throughput rides
     along ungated (trajectory only);
  4. latency attribution (``audit.timeline``) — every finished request's
     queue_wait/prefill/decode/preempted/routing decomposition must sum
     *exactly* to its end-to-end latency (exact rationals), the
     p99-TTFT phase shares and population preempted share are ledgered
     with zero tolerance, and the ``/timeline`` Chrome-trace body is
     fingerprinted alongside ``/metrics``.

    PYTHONPATH=src python benchmarks/serve_workloads.py [--smoke]
        [--ledger-dir DIR] [--update-baseline]

Prints one JSON object on the last line; ``findings`` carries the
diagnostics records scripts/smoke_all.py folds into the CI gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402

try:  # run as a module (benchmarks.run) or as a script
    from benchmarks.serve_throughput import (PAGED_COUNTER_SPECS,
                                             paged_counter_metrics)
except ImportError:  # pragma: no cover - script path
    from serve_throughput import PAGED_COUNTER_SPECS, paged_counter_metrics

#: Per-workload, per-mode SLO bounds (engine ticks / ratio).  Calibrated
#: against the deterministic traces with ~1.5x headroom over the
#: measured healthy p99s — the runs are tick-clock deterministic, so a
#: breach means the pathway changed, not that the machine was busy.
#: Full mode triples the request count over the same arrival window, so
#: its chat-peak load (and thus its honest SLO) is genuinely heavier.
SLO_BOUNDS = {
    "smoke": {
        # chat under diurnal bursts preempts at the peak: the recompute
        # inflates one request's mean gap, hence the wider gap bound and
        # the only nonzero preempted-share allowance (the share of total
        # end-to-end latency lost to preemption gaps — audit.timeline)
        "chat-diurnal": {"p99_ttft_ticks": 28.0, "p99_gap_ticks": 5.0,
                         "min_hit_rate": 0.45, "max_preempted_share": 0.30},
        "rag-heavy-tail": {"p99_ttft_ticks": 16.0, "p99_gap_ticks": 2.0,
                           "min_hit_rate": 0.55, "max_preempted_share": 0.0},
        "agent-bursty": {"p99_ttft_ticks": 6.0, "p99_gap_ticks": 2.0,
                         "min_hit_rate": 0.45, "max_preempted_share": 0.0},
    },
    "full": {
        "chat-diurnal": {"p99_ttft_ticks": 66.0, "p99_gap_ticks": 12.0,
                         "min_hit_rate": 0.55, "max_preempted_share": 0.35},
        "rag-heavy-tail": {"p99_ttft_ticks": 16.0, "p99_gap_ticks": 2.0,
                           "min_hit_rate": 0.65, "max_preempted_share": 0.0},
        "agent-bursty": {"p99_ttft_ticks": 6.0, "p99_gap_ticks": 2.0,
                         "min_hit_rate": 0.45, "max_preempted_share": 0.0},
    },
}

#: Engine geometry shared by every family (traces are sized to fit:
#: ``WorkloadTrace.max_feed`` must stay under ``max_len``).
GEOMETRY = {"slots": 3, "max_len": 64, "block_size": 8, "chunk": 4}


def _ctx(cfg):
    from repro.audit import AuditContext

    return AuditContext(workload="bench:serve_workloads", family=cfg.family,
                        arch=cfg.name, shared_prefix=True)


def _slo_rule(name: str, bounds: dict):
    from repro.audit import ExpectedSignature, Rule

    return Rule(
        name=f"workload-slo-{name}",
        workloads=("bench:serve_workloads",),
        expect=ExpectedSignature(
            p99_ttft_ticks=bounds["p99_ttft_ticks"],
            p99_decode_gap_ticks=bounds["p99_gap_ticks"],
            min_prefix_hit_rate=bounds["min_hit_rate"],
            max_preempted_share=bounds["max_preempted_share"]))


def bench(arch: str = "deepseek-7b", *, smoke: bool = True, seed: int = 0,
          ledger_dir: str | None = None,
          update_baseline: bool = False) -> dict:
    from repro.audit import (Evidence, EventLog, Ledger, MetricSpec,
                             MetricsServer, RunAudit, ServeMetrics,
                             attribution, nearest_rank)
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve import (PagedServeEngine, compare_engines, generate,
                             smoke_specs)

    mode = "smoke" if smoke else "full"
    bounds = SLO_BOUNDS[mode]
    cfg = reduced(ALL_ARCHS[arch])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    specs = smoke_specs(vocab_size=cfg.vocab_size, seed=seed)
    if not smoke:
        # full mode: same families and structure, 3x the requests (the
        # SLO bounds are per-trace, so full runs keep their own ledger)
        specs = tuple(dataclasses.replace(s, name=s.name,
                                          n_requests=3 * s.n_requests)
                      for s in specs)

    findings: list[dict] = []
    families = []
    ledger_metrics: dict[str, float] = {}

    for spec in specs:
        trace = generate(spec)
        g = GEOMETRY
        assert trace.max_feed <= g["max_len"], (spec.name, trace.max_feed)

        # ---- 1. oracle: paged must match contiguous on this family
        verify = compare_engines(model, params, trace.requests,
                                 slots=g["slots"], max_len=g["max_len"],
                                 block_size=g["block_size"],
                                 chunk=g["chunk"])
        for v in verify.verdicts:
            if not v.ok:
                findings.append({
                    "severity": "error",
                    "kind": f"serve-oracle-{spec.name}-{v.kind}",
                    "detail": v.detail})

        # ---- 2. metered paged run with live metrics off the trace hook
        audit = RunAudit(_ctx(cfg))
        audit.registry.register(_slo_rule(spec.name, bounds[spec.name]))
        log = EventLog()
        audit.tracer.subscribe(log.append)
        metrics = ServeMetrics()
        metrics.attach(audit.tracer)
        eng = PagedServeEngine(model, params, slots=g["slots"],
                               max_len=g["max_len"],
                               block_size=g["block_size"], chunk=g["chunk"],
                               tracer=audit.tracer)
        t0 = time.perf_counter()
        eng.run(trace.requests(), arrivals=trace.arrivals)
        wall = time.perf_counter() - t0
        rep = eng.report()
        metrics.observe_report(rep)

        # ---- 3. SLO judgement (pathway-slo findings on breach)
        fam_findings = audit.evaluate(engine_report=rep)
        findings.extend(fam_findings)

        lat = Evidence(tracer=audit.tracer).request_latencies()
        p99_ttft = nearest_rank([l["ttft_ticks"] for l in lat.values()], 0.99)
        gaps = [l["decode_gap_ticks"] for l in lat.values()
                if "decode_gap_ticks" in l]
        p99_gap = nearest_rank(gaps, 0.99) if gaps else 0.0
        tps = rep["tokens_out"] / max(wall, 1e-9)

        # ---- 3b. latency attribution (audit.timeline): every finished
        # request's phase decomposition must sum *exactly* to its
        # end-to-end tick latency — exact rationals, not float residue —
        # and the p99-TTFT attribution rides into the ledger
        timelines = Evidence(tracer=audit.tracer).request_timelines()
        closed = [tl for tl in timelines.values() if tl.end is not None]
        share_sum_exact = all(sum(tl.shares().values()) == 1
                              for tl in closed)
        if not share_sum_exact:
            findings.append({
                "severity": "error",
                "kind": f"timeline-inexact-{spec.name}",
                "detail": "per-request phase shares do not sum to 1 "
                          "exactly: the span partition leaked time"})
        att = attribution(timelines) or {
            "p99_shares": {}, "preempted_share": 0.0,
            "dominant_phase": None, "p99_rid": None}

        # the exposition layer is part of the measured pathway: render
        # both formats through the pure handler and fingerprint the
        # bytes — same seed + trace must reproduce them exactly
        server = MetricsServer(metrics.registry, log)
        _, _, prom = server.handle("/metrics")
        _, _, snap = server.handle("/metrics.json")
        _, _, tline = server.handle("/timeline")
        assert server.handle("/metrics")[2] == prom  # render is pure
        assert server.handle("/timeline")[2] == tline

        key = spec.name.replace("-", "_")
        ledger_metrics.update({
            f"{key}_p99_ttft_ticks": float(p99_ttft),
            f"{key}_p99_gap_ticks": float(p99_gap),
            f"{key}_prefix_hit_rate": float(rep["prefix_hit_rate"]),
            f"{key}_tokens_out": float(rep["tokens_out"]),
            f"{key}_tokens_per_s": round(tps, 1),
            f"{key}_queue_share_p99": round(
                att["p99_shares"].get("queue_wait", 0.0), 6),
            f"{key}_prefill_share_p99": round(
                att["p99_shares"].get("prefill", 0.0), 6),
            f"{key}_preempted_share": round(att["preempted_share"], 6),
            f"{key}_share_sum_exact": 1.0 if share_sum_exact else 0.0,
        })
        families.append({
            "workload": trace.describe(),
            "oracle_ok": verify.ok,
            "p99_ttft_ticks": round(float(p99_ttft), 2),
            "p99_decode_gap_ticks": round(float(p99_gap), 3),
            "slo": bounds[spec.name],
            "slo_findings": [f for f in fam_findings
                             if f["kind"] in ("pathway-slo",
                                              "pathway-attribution")],
            "tokens_per_s": round(tps, 1),
            "preemptions": rep["preemptions"],
            "attribution": {
                "dominant_phase": att["dominant_phase"],
                "p99_rid": att["p99_rid"],
                "p99_shares": {k: round(v, 4)
                               for k, v in att["p99_shares"].items()},
                "preempted_share": round(att["preempted_share"], 4),
                "share_sum_exact": share_sum_exact,
            },
            "report": {k: rep[k] for k in
                       ("decode_steps", "tokens_out", "prefix_hit_rate",
                        "cached_tokens", "page_peak_utilization")},
            "metrics": {
                "events_logged": len(log),
                "prometheus_sha256": hashlib.sha256(prom).hexdigest(),
                "snapshot_sha256": hashlib.sha256(snap).hexdigest(),
                "timeline_sha256": hashlib.sha256(tline).hexdigest(),
                "p99_ttft_bucket": metrics.ttft.quantile(0.99),
                "finished": metrics.finished.value,
            },
        })

    # ---- ledger: deterministic per-family SLO counters gated tight,
    # wall-clock throughput recorded ungated
    ledger_out = None
    if ledger_dir is not None:
        ledger = Ledger(ledger_dir)
        specs_l = []
        for name in ledger_metrics:
            if name.endswith("_tokens_per_s"):
                specs_l.append(MetricSpec(name, gate=False))
            elif name.endswith(("_p99_ttft_ticks", "_p99_gap_ticks")):
                specs_l.append(MetricSpec(name, higher_is_better=False,
                                          rel_tol=0.1))
            elif name.endswith("_prefix_hit_rate"):
                specs_l.append(MetricSpec(name, higher_is_better=True,
                                          rel_tol=0.05))
            elif name.endswith(("_queue_share_p99", "_prefill_share_p99",
                                "_preempted_share")):
                # attribution shares are deterministic functions of the
                # tick schedule: any drift is a pathway change
                specs_l.append(MetricSpec(name, higher_is_better=False,
                                          rel_tol=0.0))
            else:  # tokens_out / share_sum_exact: exact
                specs_l.append(MetricSpec(name, higher_is_better=True,
                                          rel_tol=0.0))
        bench_key = f"serve_workloads_{mode}"
        res = ledger.compare(bench_key, ledger_metrics, specs_l,
                             update_baseline=update_baseline)
        findings.extend(res.findings)
        ledger_out = {"baseline_written": res.baseline_written,
                      "deltas": res.deltas,
                      "path": str(ledger.path(bench_key))}

    return {
        "bench": "serve_workloads",
        "arch": cfg.name,
        "mode": mode,
        "oracle_ok": all(f["oracle_ok"] for f in families),
        "slo_ok": not any(f["slo_findings"] for f in families),
        "families": families,
        "ledger": ledger_out,
        "findings": findings,
    }


def run():
    """benchmarks.run CSV protocol."""
    res = bench(smoke=True)
    n_err = sum(1 for f in res["findings"] if f["severity"] == "error")
    if n_err:
        raise RuntimeError(f"serve_workloads: {n_err} error finding(s): "
                           + "; ".join(f["detail"] for f in res["findings"]
                                       if f["severity"] == "error"))
    for fam in res["families"]:
        yield {"name": f"serve_workloads.{fam['workload']['workload']}",
               "us_per_call": 1e6 / max(fam["tokens_per_s"], 1e-9),
               "derived": (f"p99_ttft={fam['p99_ttft_ticks']} "
                           f"hit_rate={fam['report']['prefix_hit_rate']} "
                           f"oracle_ok={fam['oracle_ok']}")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ledger-dir", default=None,
                    help="BENCH_*.json directory; omit to skip the ledger")
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args()
    print(json.dumps(bench(args.arch, smoke=args.smoke, seed=args.seed,
                           ledger_dir=args.ledger_dir,
                           update_baseline=args.update_baseline)))


if __name__ == "__main__":
    main()
