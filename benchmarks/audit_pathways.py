"""Audit-pathway benchmark: the detector must catch what the oracle can't.

``compare_engines`` proves two serving pathways emit identical greedy
token streams — it is blind to *how* they got there.  This benchmark
seeds three misconfigurations that keep outputs token-identical while
degrading the pathway (the paper's "suboptimal transport pathway" class,
§8), and asserts the audit pipeline flags each as an error:

  1. forced contiguous fallback on a dense arch (full-batch per-token
     prefill instead of paged chunked prefill);
  2. shrunk page size (per-page overhead up, prefix granularity down);
  3. disabled prefix cache (every admission recomputes the shared
     prefix).

A detector miss — a seeded run the registry does NOT flag — is itself an
``error`` finding, so CI gates on the audit pipeline's sensitivity, not
just on the healthy run being clean.  The healthy run's deterministic
counters (decode steps, cached tokens, hit rate) and throughput go into
the persisted ``BENCH_*.json`` ledger with regression thresholds.

    PYTHONPATH=src python benchmarks/audit_pathways.py [--smoke]
        [--ledger-dir DIR] [--update-baseline]

Prints one JSON object on the last line; ``findings`` carries the
diagnostics records scripts/smoke_all.py folds into the CI gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402

try:  # run as a module (benchmarks.run) or as a script
    from benchmarks.serve_throughput import (PAGED_COUNTER_SPECS,
                                             _trace_factory,
                                             paged_counter_metrics)
except ImportError:  # pragma: no cover - script path
    from serve_throughput import (PAGED_COUNTER_SPECS, _trace_factory,
                                  paged_counter_metrics)

#: What each seeded misconfiguration must trip in the registry.
SEEDS = {
    "contiguous-fallback": "pathway-engine-selection",
    "shrunk-page-size": "pathway-page-geometry",
    "disabled-prefix-cache": "pathway-prefix-cache",
}


def _ctx(cfg, shared_prefix=True):
    from repro.audit import AuditContext

    return AuditContext(workload="bench:audit_pathways", family=cfg.family,
                        arch=cfg.name, shared_prefix=shared_prefix)


def bench(arch: str = "deepseek-7b", *, smoke: bool = False, seed: int = 0,
          ledger_dir: str | None = None,
          update_baseline: bool = False) -> dict:
    from repro.audit import Ledger, MetricSpec, RunAudit
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import (PagedServeEngine, ServeEngine,
                                    compare_engines, token_matrix)

    if smoke:
        n_req, shared, tails, max_new = 6, 16, (3, 6), 4
        slots, max_len, block, chunk = 2, 48, 8, 4
    else:
        n_req, shared, tails, max_new = 12, 32, (4, 10), 8
        slots, max_len, block, chunk = 4, 96, 8, 8

    cfg = reduced(ALL_ARCHS[arch])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    make = _trace_factory(cfg.vocab_size, n_requests=n_req,
                          shared_len=shared, tail_lo=tails[0],
                          tail_hi=tails[1], max_new=max_new, seed=seed)
    findings: list[dict] = []

    # ------------------------------------------------ oracle stays green
    verify = compare_engines(model, params, make, slots=slots,
                             max_len=max_len, block_size=block, chunk=chunk)
    for v in verify.verdicts:
        if not v.ok:
            findings.append({"severity": "error",
                             "kind": f"serve-oracle-{v.kind}",
                             "detail": v.detail})

    # --------------------------------------------------- healthy pathway
    audit = RunAudit(_ctx(cfg))
    eng = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                           block_size=block, chunk=chunk,
                           tracer=audit.tracer)
    t0 = time.perf_counter()
    done = eng.run(make())
    wall = time.perf_counter() - t0
    healthy_tokens = token_matrix(done, n_req, max_new)
    rep = eng.report()
    healthy = audit.evaluate(engine_report=rep)
    findings.extend(healthy)        # a dirty healthy run is a real failure

    # ------------------------------------------- seeded misconfigurations
    def contiguous_fallback(tracer):
        return ServeEngine(model, params, slots=slots, max_len=max_len,
                           tracer=tracer)

    def shrunk_page(tracer):
        return PagedServeEngine(model, params, slots=slots, max_len=max_len,
                                block_size=2, chunk=chunk, tracer=tracer)

    def no_prefix_cache(tracer):
        return PagedServeEngine(model, params, slots=slots, max_len=max_len,
                                block_size=block, chunk=chunk,
                                use_prefix_cache=False, tracer=tracer)

    builders = {"contiguous-fallback": contiguous_fallback,
                "shrunk-page-size": shrunk_page,
                "disabled-prefix-cache": no_prefix_cache}
    detections = {}
    for name, build_eng in builders.items():
        s_audit = RunAudit(_ctx(cfg))
        s_eng = build_eng(s_audit.tracer)
        s_done = s_eng.run(make())
        s_findings = s_audit.evaluate(engine_report=s_eng.report())
        hit = [f for f in s_findings
               if f["kind"] == SEEDS[name] and f["severity"] == "error"]
        token_identical = bool(
            (token_matrix(s_done, n_req, max_new) == healthy_tokens).all())
        detections[name] = {
            "detected": bool(hit),
            "expected_kind": SEEDS[name],
            "findings": s_findings,
            "token_identical": token_identical,
        }
        if not hit:
            findings.append({
                "severity": "error", "kind": "audit-detector-miss",
                "detail": f"seeded misconfiguration {name!r} was not "
                          f"flagged as {SEEDS[name]} "
                          f"(got {[f['kind'] for f in s_findings]})"})
        if not token_identical:
            findings.append({
                "severity": "error", "kind": "audit-seed-divergence",
                "detail": f"seeded misconfiguration {name!r} changed the "
                          f"token stream — it must degrade the pathway, "
                          f"not the answer"})

    # --------------------------------- perf ledger (opt-in, like every
    # serving benchmark: only a caller that names a ledger dir gates on
    # baselines, so bare benchmark runs never write repo-root state)
    metrics = {
        **paged_counter_metrics(rep),
        "tokens_per_s": round(rep["tokens_out"] / max(wall, 1e-9), 1),
    }
    ledger_out = None
    if ledger_dir is not None:
        ledger = Ledger(ledger_dir)
        # shared deterministic counter bands + this benchmark's
        # wall-clock throughput (tracked, not gated: CPU CI noise)
        specs = (PAGED_COUNTER_SPECS
                 + [MetricSpec("tokens_per_s", gate=False)])
        # smoke and full traces have different shapes: separate baselines
        bench_key = f"audit_pathways_{'smoke' if smoke else 'full'}"
        ledger_res = ledger.compare(bench_key, metrics, specs,
                                    update_baseline=update_baseline)
        findings.extend(ledger_res.findings)
        ledger_out = {"baseline_written": ledger_res.baseline_written,
                      "deltas": ledger_res.deltas,
                      "path": str(ledger.path(bench_key))}

    return {
        "bench": "audit_pathways",
        "arch": cfg.name,
        "mode": "smoke" if smoke else "full",
        "oracle_ok": verify.ok,
        "healthy_findings": healthy,
        "detections": detections,
        "detected_all": all(d["detected"] for d in detections.values()),
        "trace": audit.tracer.summary(),
        "metrics": metrics,
        "ledger": ledger_out,
        "findings": findings,
    }


def run():
    """benchmarks.run CSV protocol."""
    res = bench(smoke=True)
    n_err = sum(1 for f in res["findings"] if f["severity"] == "error")
    if n_err:
        raise RuntimeError(f"audit_pathways: {n_err} error finding(s): "
                           + "; ".join(f["detail"] for f in res["findings"]
                                       if f["severity"] == "error"))
    yield {"name": "audit_pathways.detectors",
           "us_per_call": 0.0,
           "derived": (f"detected_all={res['detected_all']} "
                       f"oracle_ok={res['oracle_ok']}")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ledger-dir", default=None,
                    help="BENCH_*.json directory; omit to skip the ledger")
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args()
    print(json.dumps(bench(args.arch, smoke=args.smoke, seed=args.seed,
                           ledger_dir=args.ledger_dir,
                           update_baseline=args.update_baseline)))


if __name__ == "__main__":
    main()
