"""Audit-pathway benchmark: the detector must catch what the oracle can't.

``compare_engines`` proves two serving pathways emit identical token
streams (greedy and sampled) — it is blind to *how* they got there.
This benchmark seeds misconfigurations that keep outputs
token-identical while degrading the pathway (the paper's "suboptimal
transport pathway" class, §8), and asserts the audit pipeline flags each
as an error:

  1. forced contiguous fallback on a dense arch (full-batch per-token
     prefill instead of paged chunked prefill);
  2. shrunk page size (per-page overhead up, prefix granularity down);
  3. disabled prefix cache (every admission recomputes the shared
     prefix);
  4. slow admission (scheduler only consulted every N-th tick): streams
     are unchanged but per-request TTFT inflates — caught by the
     registry's per-request latency expectations over the lifecycle
     trace events (submit / first-token / finish);
  5. gather fallback on the paged engine (``kernel="gather"``): KV
     copied into a dense per-slot working cache at admission instead of
     attended through the device page table — the contiguous-shaped
     detour the paged-attention kernel exists to remove, flagged
     ``pathway-kernel``;
  6. preemption disabled under bursty overload: long low-priority
     requests hold every slot when a high-priority burst arrives, and
     with no eviction the burst queues behind them.  Streams stay
     identical (admission still sorts by priority; deterministic
     sampling is schedule-invariant; recompute-on-readmit reproduces
     the healthy streams) but the burst's tail TTFT explodes — caught
     by the registry's *quantile* SLO expectations (``pathway-slo``),
     calibrated from a healthy preemption-on run of the same
     generated bursty trace;
  7. random routing on a 3-replica cluster: counter-based sampling is
     placement-independent, so scattering a shared-prefix chat trace
     uniformly across replicas keeps every stream bit-identical to the
     prefix-affine run — while ``routed_affinity`` collapses toward
     1/replicas and the cluster-wide prefix hit rate drops (each
     replica recomputes prefixes a sibling already holds).  Caught by
     ``pathway-routing`` floors calibrated from the healthy affinity
     run of the same trace;
  8. admission throttle on a staggered-arrival trace with ample slots:
     the scheduler is consulted every N-th tick, so requests sit queued
     for whole scheduling epochs while slots idle.  Streams stay
     identical and even the aggregate SLO can look like "slow machine"
     — the *attribution* detector (``audit.timeline``) decomposes the
     p99-TTFT request's latency into exact phase shares and flags that
     queue_wait, not prefill, dominates (``pathway-attribution``),
     against share bounds calibrated from the healthy run of the same
     trace.  This is the layer that turns "an SLO regressed" into
     "queue wait ate the p99";
  9. swap tier disabled under the same bursty overload: preemption
     still fires, but every readmission re-prefills prompt +
     generated-so-far instead of swapping the victim's host-parked KV
     pages back in.  Recompute reproduces the identical streams by
     construction, so no output check can see it — caught by
     ``pathway-tiering`` expectations (restore-rate floor and
     recompute-token ceiling) calibrated from the healthy swap-on run
     of the same trace.

A request-lifecycle probe additionally runs sampled + cancelled requests
through the audited pathway and gates on their events being visible in
the trace and on cancellation releasing every page reference.

A detector miss — a seeded run the registry does NOT flag — is itself an
``error`` finding, so CI gates on the audit pipeline's sensitivity, not
just on the healthy run being clean.  The healthy run's deterministic
counters (decode steps, cached tokens, hit rate) and throughput go into
the persisted ``BENCH_*.json`` ledger with regression thresholds.

    PYTHONPATH=src python benchmarks/audit_pathways.py [--smoke]
        [--ledger-dir DIR] [--update-baseline]

Prints one JSON object on the last line; ``findings`` carries the
diagnostics records scripts/smoke_all.py folds into the CI gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402

try:  # run as a module (benchmarks.run) or as a script
    from benchmarks.serve_throughput import (PAGED_COUNTER_SPECS,
                                             _trace_factory,
                                             paged_counter_metrics)
except ImportError:  # pragma: no cover - script path
    from serve_throughput import (PAGED_COUNTER_SPECS, _trace_factory,
                                  paged_counter_metrics)

#: What each seeded misconfiguration must trip in the registry.
SEEDS = {
    "contiguous-fallback": "pathway-engine-selection",
    "gather-fallback": "pathway-kernel",
    "shrunk-page-size": "pathway-page-geometry",
    "disabled-prefix-cache": "pathway-prefix-cache",
    "slow-admission": "pathway-ttft",
    "bursty-overload-no-preemption": "pathway-slo",
    "random-routing": "pathway-routing",
    "admission-throttle": "pathway-attribution",
    "swap-disabled-recompute": "pathway-tiering",
}

#: Routing floors as fractions of the healthy affinity run's values
#: (deterministic tick-clock runs: the margins separate affinity from
#: uniform-random over 3 replicas, they do not absorb noise).
AFFINITY_FLOOR_FRAC = 0.8
SHARED_HIT_FLOOR_FRAC = 0.85

#: Slow-admission seed: scheduler consulted every N-th tick only.
ADMIT_EVERY = 8

#: TTFT bound = this factor over the healthy run's worst per-request
#: TTFT (both runs are deterministic on the synthetic tick clock, so
#: the margin only needs to separate healthy jitter=0 from the seeded
#: inflation, not absorb noise).
TTFT_MARGIN = 1.25

#: Attribution seed: scheduler consulted every N-th tick on a
#: staggered-arrival trace whose slot count matches the offered load —
#: healthy queue share is small, throttled requests wait most of an
#: epoch.  The share bounds use the same calibrated-margin idea as
#: TTFT_MARGIN (deterministic runs: margins separate, they don't absorb
#: noise).
ATTR_ADMIT_EVERY = 10
ATTR_MARGIN = 1.25


def _ctx(cfg, shared_prefix=True):
    from repro.audit import AuditContext

    return AuditContext(workload="bench:audit_pathways", family=cfg.family,
                        arch=cfg.name, shared_prefix=shared_prefix)


def bench(arch: str = "deepseek-7b", *, smoke: bool = False, seed: int = 0,
          ledger_dir: str | None = None,
          update_baseline: bool = False) -> dict:
    from repro.audit import (Evidence, ExpectedSignature, Ledger, MetricSpec,
                             Rule, RunAudit, attribution)
    from repro.serve import (PagedServeEngine, SamplingParams, ServeEngine,
                             compare_engines, token_matrix)
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build

    if smoke:
        n_req, shared, tails, max_new = 6, 16, (3, 6), 4
        slots, max_len, block, chunk = 2, 48, 8, 4
    else:
        n_req, shared, tails, max_new = 12, 32, (4, 10), 8
        slots, max_len, block, chunk = 4, 96, 8, 8

    cfg = reduced(ALL_ARCHS[arch])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    make = _trace_factory(cfg.vocab_size, n_requests=n_req,
                          shared_len=shared, tail_lo=tails[0],
                          tail_hi=tails[1], max_new=max_new, seed=seed)
    findings: list[dict] = []

    # ------------------------------------------------ oracle stays green
    # greedy AND sampled: counter-based per-request PRNG keys make the
    # sampled streams engine-independent, so the dual-environment verdict
    # is the same bit-identity in both modes
    sampled = SamplingParams(temperature=0.8, top_k=20, top_p=0.95,
                             seed=seed + 1)
    oracle_ok: dict[str, bool] = {}
    for mode, sp in (("greedy", None), ("sampled", sampled)):
        verify = compare_engines(model, params, make, slots=slots,
                                 max_len=max_len, block_size=block,
                                 chunk=chunk, sampling=sp)
        oracle_ok[mode] = verify.ok
        for v in verify.verdicts:
            if not v.ok:
                findings.append({"severity": "error",
                                 "kind": f"serve-oracle-{mode}-{v.kind}",
                                 "detail": v.detail})

    # --------------------------------------------------- healthy pathway
    audit = RunAudit(_ctx(cfg))
    eng = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                           block_size=block, chunk=chunk,
                           tracer=audit.tracer)
    t0 = time.perf_counter()
    done = eng.run(make())
    wall = time.perf_counter() - t0
    healthy_tokens = token_matrix(done, n_req, max_new)
    rep = eng.report()

    # calibrate the per-request latency expectation from the healthy
    # run's lifecycle events: the schedule is deterministic (synthetic
    # tick clock), so the bound is a clean separator, not a noise band
    healthy_lat = Evidence(tracer=audit.tracer).request_latencies()
    ttft_bound = TTFT_MARGIN * max(
        latency["ttft_ticks"] for latency in healthy_lat.values())
    ttft_rule = Rule(
        name="bench-ttft-slo", families=("dense", "moe"),
        workloads=("bench:audit_pathways",),
        expect=ExpectedSignature(max_ttft_ticks=ttft_bound))
    audit.registry.register(ttft_rule)

    healthy = audit.evaluate(engine_report=rep)
    findings.extend(healthy)        # a dirty healthy run is a real failure

    # ------------------------------------------- seeded misconfigurations
    def contiguous_fallback(tracer):
        return ServeEngine(model, params, slots=slots, max_len=max_len,
                           tracer=tracer)

    def gather_fallback(tracer):
        return PagedServeEngine(model, params, slots=slots, max_len=max_len,
                                block_size=block, chunk=chunk,
                                kernel="gather", tracer=tracer)

    def shrunk_page(tracer):
        return PagedServeEngine(model, params, slots=slots, max_len=max_len,
                                block_size=2, chunk=chunk, tracer=tracer)

    def no_prefix_cache(tracer):
        return PagedServeEngine(model, params, slots=slots, max_len=max_len,
                                block_size=block, chunk=chunk,
                                use_prefix_cache=False, tracer=tracer)

    def slow_admission(tracer):
        return PagedServeEngine(model, params, slots=slots, max_len=max_len,
                                block_size=block, chunk=chunk,
                                admit_every=ADMIT_EVERY, tracer=tracer)

    builders = {"contiguous-fallback": contiguous_fallback,
                "gather-fallback": gather_fallback,
                "shrunk-page-size": shrunk_page,
                "disabled-prefix-cache": no_prefix_cache,
                "slow-admission": slow_admission}
    detections = {}
    for name, build_eng in builders.items():
        s_audit = RunAudit(_ctx(cfg))
        # the latency SLO applies to every paged seeded run (the
        # contiguous fallback ticks a different clock, so the bound is
        # not comparable).  Other seeds may legitimately trip it too —
        # recomputing the shared prefix (disabled cache) also delays
        # first tokens; detection below only requires the *expected*
        # kind to be present, not exclusivity.
        if name != "contiguous-fallback":
            s_audit.registry.register(ttft_rule)
        s_eng = build_eng(s_audit.tracer)
        s_done = s_eng.run(make())
        s_findings = s_audit.evaluate(engine_report=s_eng.report())
        hit = [f for f in s_findings
               if f["kind"] == SEEDS[name] and f["severity"] == "error"]
        token_identical = bool(
            (token_matrix(s_done, n_req, max_new) == healthy_tokens).all())
        detections[name] = {
            "detected": bool(hit),
            "expected_kind": SEEDS[name],
            "findings": s_findings,
            "token_identical": token_identical,
        }
        if not hit:
            findings.append({
                "severity": "error", "kind": "audit-detector-miss",
                "detail": f"seeded misconfiguration {name!r} was not "
                          f"flagged as {SEEDS[name]} "
                          f"(got {[f['kind'] for f in s_findings]})"})
        if not token_identical:
            findings.append({
                "severity": "error", "kind": "audit-seed-divergence",
                "detail": f"seeded misconfiguration {name!r} changed the "
                          f"token stream — it must degrade the pathway, "
                          f"not the answer"})

    # ------------------- seed 6: bursty overload, preemption disabled.
    # A generated bursty trace: two long low-priority requests arrive
    # first and saturate both slots of a dedicated small engine; pairs
    # of short high-priority requests burst in afterwards.  With
    # preemption the bursts evict the lows and see fast first tokens;
    # with it disabled they queue behind ~40 ticks of low-priority
    # decode.  The max-TTFT rule cannot cleanly catch this (the healthy
    # run's preempted lows also wait), so this seed is the quantile
    # SLO's reason to exist: p99 TTFT is calibrated from the healthy
    # preemption-on run of the *same* trace and breached only when the
    # scheduler misconfiguration inflates the tail.
    from repro.serve import WorkloadSpec, generate

    ov_spec = WorkloadSpec(
        name="bursty-overload", family="chat", arrival="bursty",
        n_requests=10, vocab_size=cfg.vocab_size, seed=seed + 7,
        max_new=4, prefix_len=12, n_streams=2, suffix_lo=2, suffix_hi=4,
        burst_size=2, burst_gap=12.0,
        priorities=(0, 0, 2, 2, 2, 2, 2, 2, 2, 2))
    ov_trace = generate(ov_spec)
    ov_geom = dict(slots=2, max_len=64, block_size=8, chunk=4)
    LOW_MAX_NEW = 40

    def ov_requests():
        reqs = ov_trace.requests()
        for r in reqs[:2]:
            r.max_new = LOW_MAX_NEW     # the lows run long
        return reqs

    def ov_run(preemption: bool, swap: bool = True):
        a = RunAudit(_ctx(cfg))
        e = PagedServeEngine(model, params, preemption=preemption,
                             swap=swap, tracer=a.tracer, **ov_geom)
        d = e.run(ov_requests(), arrivals=list(ov_trace.arrivals))
        return a, e, token_matrix(d, ov_spec.n_requests, LOW_MAX_NEW)

    ov_audit, ov_eng, ov_tokens = ov_run(preemption=True)
    ov_rep = ov_eng.report()
    ov_lat = Evidence(tracer=ov_audit.tracer).request_latencies()
    from repro.audit import nearest_rank
    ov_p99 = nearest_rank(
        [latency["ttft_ticks"] for latency in ov_lat.values()], 0.99)
    slo_rule = Rule(
        name="bench-burst-slo", families=("dense", "moe"),
        workloads=("bench:audit_pathways",),
        expect=ExpectedSignature(p99_ttft_ticks=TTFT_MARGIN * ov_p99))
    # tiering expectations calibrated from the same healthy run: the
    # swap-on baseline restores its own preempted work, so half its
    # restore rate is a generous floor and its recompute count an exact
    # ceiling (the healthy run trivially satisfies both).
    tier_rule = Rule(
        name="bench-swap-tiering", families=("dense", "moe"),
        workloads=("bench:audit_pathways",),
        expect=ExpectedSignature(
            min_swap_restore_rate=0.5 * ov_rep["swap_restore_rate"],
            max_recompute_tokens=int(ov_rep["recompute_tokens"])))
    ov_audit.registry.register(slo_rule)
    ov_audit.registry.register(tier_rule)
    ov_healthy = ov_audit.evaluate(engine_report=ov_rep)
    findings.extend(ov_healthy)     # calibrated on itself: must be clean

    s_audit, s_eng, s_tokens = ov_run(preemption=False)
    s_audit.registry.register(slo_rule)
    s_findings = s_audit.evaluate(engine_report=s_eng.report())
    s_lat = Evidence(tracer=s_audit.tracer).request_latencies()
    name = "bursty-overload-no-preemption"
    hit = [f for f in s_findings
           if f["kind"] == SEEDS[name] and f["severity"] == "error"]
    token_identical = bool((s_tokens == ov_tokens).all())
    detections[name] = {
        "detected": bool(hit),
        "expected_kind": SEEDS[name],
        "findings": s_findings,
        "token_identical": token_identical,
        "healthy_preemptions": ov_eng.sched.stats.preemptions,
        "seeded_preemptions": s_eng.sched.stats.preemptions,
        "healthy_p99_ttft": round(ov_p99, 2),
        "seeded_p99_ttft": round(nearest_rank(
            [latency["ttft_ticks"] for latency in s_lat.values()], 0.99), 2),
    }
    if not hit:
        findings.append({
            "severity": "error", "kind": "audit-detector-miss",
            "detail": f"seeded misconfiguration {name!r} was not flagged "
                      f"as {SEEDS[name]} "
                      f"(got {[f['kind'] for f in s_findings]})"})
    if not token_identical:
        findings.append({
            "severity": "error", "kind": "audit-seed-divergence",
            "detail": f"seeded misconfiguration {name!r} changed the "
                      f"token stream — it must degrade the pathway, "
                      f"not the answer"})
    if ov_eng.sched.stats.preemptions == 0:
        findings.append({
            "severity": "error", "kind": "audit-seed-uncontrasted",
            "detail": "bursty-overload trace never triggered preemption "
                      "in the healthy run: the seed contrasts nothing"})

    # --------------------- seed 9: swap tier disabled, preemption kept.
    # Same bursty trace, preemption on, ``swap=False``: victims drop
    # their pages on eviction and readmission re-prefills everything
    # previously computed.  Recompute reproduces the identical streams
    # (that equivalence is the engine's readmission contract), so the
    # degradation is invisible to every output check — the calibrated
    # restore-rate floor and recompute ceiling must flag it.
    t_audit, t_eng, t_tokens = ov_run(preemption=True, swap=False)
    t_audit.registry.register(tier_rule)
    t_rep = t_eng.report()
    t_findings = t_audit.evaluate(engine_report=t_rep)
    name = "swap-disabled-recompute"
    hit = [f for f in t_findings
           if f["kind"] == SEEDS[name] and f["severity"] == "error"]
    token_identical = bool((t_tokens == ov_tokens).all())
    detections[name] = {
        "detected": bool(hit),
        "expected_kind": SEEDS[name],
        "findings": t_findings,
        "token_identical": token_identical,
        "healthy_restore_rate": ov_rep["swap_restore_rate"],
        "healthy_recompute_tokens": ov_rep["recompute_tokens"],
        "seeded_restore_rate": t_rep["swap_restore_rate"],
        "seeded_recompute_tokens": t_rep["recompute_tokens"],
    }
    if not hit:
        findings.append({
            "severity": "error", "kind": "audit-detector-miss",
            "detail": f"seeded misconfiguration {name!r} was not flagged "
                      f"as {SEEDS[name]} "
                      f"(got {[f['kind'] for f in t_findings]})"})
    if not token_identical:
        findings.append({
            "severity": "error", "kind": "audit-seed-divergence",
            "detail": f"seeded misconfiguration {name!r} changed the "
                      f"token stream — recompute-on-readmit must "
                      f"reproduce the swap-restored streams exactly"})
    if ov_rep["restored_tokens"] == 0:
        findings.append({
            "severity": "error", "kind": "audit-seed-uncontrasted",
            "detail": "healthy bursty run never restored swapped pages: "
                      "the tiering seed contrasts nothing"})

    # --------------------- seed 7: random routing on a 3-replica cluster.
    # The same multi-tenant chat trace (shared prefixes + arrivals spread
    # over ticks, so later requests route against warm summaries) run
    # twice: prefix-affinity routing calibrates the ``pathway-routing``
    # floors; uniform-random routing must stay token-identical yet breach
    # them — the misconfiguration no output check can see.
    from repro.serve import ClusterEngine, smoke_specs

    cl_spec = smoke_specs(vocab_size=cfg.vocab_size, seed=seed)[0]  # chat
    cl_trace = generate(cl_spec)
    cl_geom = dict(slots=2, max_len=48, block_size=8, chunk=4)
    CL_MAX_NEW = 4

    def cl_requests():
        reqs = cl_trace.requests()
        for r in reqs:
            r.max_new = CL_MAX_NEW
        return reqs

    def cl_run(routing: str):
        a = RunAudit(_ctx(cfg))
        e = ClusterEngine(model, params, replicas=3, routing=routing,
                          routing_seed=seed + 11, tracer=a.tracer,
                          **cl_geom)
        d = e.run(cl_requests(), arrivals=list(cl_trace.arrivals))
        return a, e, token_matrix(d, cl_spec.n_requests, CL_MAX_NEW)

    cl_audit, cl_eng, cl_tokens = cl_run("affinity")
    cl_rep = cl_eng.report()
    routing_rule = Rule(
        name="bench-cluster-routing", families=("dense", "moe"),
        workloads=("bench:audit_pathways",),
        expect=ExpectedSignature(
            min_routed_affinity=AFFINITY_FLOOR_FRAC
            * cl_rep["routed_affinity"],
            min_shared_hit_rate=SHARED_HIT_FLOOR_FRAC
            * cl_rep["shared_hit_rate"]))
    cl_audit.registry.register(routing_rule)
    cl_healthy = cl_audit.evaluate(engine_report=cl_rep)
    findings.extend(cl_healthy)     # calibrated on itself: must be clean

    s_audit, s_eng, s_tokens = cl_run("random")
    s_audit.registry.register(routing_rule)
    s_rep = s_eng.report()
    s_findings = s_audit.evaluate(engine_report=s_rep)
    name = "random-routing"
    hit = [f for f in s_findings
           if f["kind"] == SEEDS[name] and f["severity"] == "error"]
    token_identical = bool((s_tokens == cl_tokens).all())
    detections[name] = {
        "detected": bool(hit),
        "expected_kind": SEEDS[name],
        "findings": s_findings,
        "token_identical": token_identical,
        "healthy_affinity": cl_rep["routed_affinity"],
        "seeded_affinity": s_rep["routed_affinity"],
        "healthy_shared_hit": cl_rep["shared_hit_rate"],
        "seeded_shared_hit": s_rep["shared_hit_rate"],
        "affine_opportunities": cl_rep["affine_opportunities"],
    }
    if not hit:
        findings.append({
            "severity": "error", "kind": "audit-detector-miss",
            "detail": f"seeded misconfiguration {name!r} was not flagged "
                      f"as {SEEDS[name]} "
                      f"(got {[f['kind'] for f in s_findings]})"})
    if not token_identical:
        findings.append({
            "severity": "error", "kind": "audit-seed-divergence",
            "detail": f"seeded misconfiguration {name!r} changed the "
                      f"token stream — it must degrade the pathway, "
                      f"not the answer"})
    if cl_rep["affine_opportunities"] == 0:
        findings.append({
            "severity": "error", "kind": "audit-seed-uncontrasted",
            "detail": "chat trace offered the cluster router no affinity "
                      "opportunity in the healthy run: the seed "
                      "contrasts nothing"})

    # ------------------- seed 8: admission throttle → phase attribution.
    # A dedicated staggered-arrival trace (one request every 3 ticks)
    # on an engine with slots ≈ load: healthily each request admits on
    # the next tick, so its TTFT is almost all prefill.  With the
    # scheduler consulted only every ATTR_ADMIT_EVERY ticks, requests
    # queue for most of a scheduling epoch while slots idle — the
    # schedule shifts, the streams don't (greedy decode is schedule-
    # invariant).  The timeline detector must both FIRE and LOCALIZE:
    # the pathway-attribution finding has to name queue_wait as the
    # dominant phase of the p99-TTFT request.
    at_geom = dict(slots=3, max_len=48, block_size=8, chunk=4)
    AT_N, AT_MAX_NEW = 6, 4
    at_make = _trace_factory(cfg.vocab_size, n_requests=AT_N,
                             shared_len=16, tail_lo=3, tail_hi=6,
                             max_new=AT_MAX_NEW, seed=seed + 13)
    at_arrivals = [float(3 * i) for i in range(AT_N)]

    def at_run(admit_every: int):
        a = RunAudit(_ctx(cfg))
        e = PagedServeEngine(model, params, admit_every=admit_every,
                             tracer=a.tracer, **at_geom)
        d = e.run(at_make(), arrivals=list(at_arrivals))
        return a, e, token_matrix(d, AT_N, AT_MAX_NEW)

    at_audit, at_eng, at_tokens = at_run(1)
    at_att = attribution(
        Evidence(tracer=at_audit.tracer).request_timelines())
    attr_rule = Rule(
        name="bench-attribution", families=("dense", "moe"),
        workloads=("bench:audit_pathways",),
        expect=ExpectedSignature(
            max_queue_share_p99=min(
                0.9, ATTR_MARGIN * at_att["p99_shares"]["queue_wait"]),
            max_prefill_share_p99=min(
                0.98, ATTR_MARGIN * at_att["p99_shares"]["prefill"]),
            max_preempted_share=0.0))
    at_audit.registry.register(attr_rule)
    at_healthy = at_audit.evaluate(engine_report=at_eng.report())
    findings.extend(at_healthy)     # calibrated on itself: must be clean

    s_audit, s_eng, s_tokens = at_run(ATTR_ADMIT_EVERY)
    s_audit.registry.register(attr_rule)
    s_findings = s_audit.evaluate(engine_report=s_eng.report())
    s_att = attribution(Evidence(tracer=s_audit.tracer).request_timelines())
    name = "admission-throttle"
    hit = [f for f in s_findings
           if f["kind"] == SEEDS[name] and f["severity"] == "error"]
    token_identical = bool((s_tokens == at_tokens).all())
    localized = any("dominant phase: queue_wait" in f["detail"]
                    for f in hit)
    detections[name] = {
        "detected": bool(hit),
        "expected_kind": SEEDS[name],
        "findings": s_findings,
        "token_identical": token_identical,
        "localized_queue_wait": localized,
        "healthy_queue_share_p99": round(
            at_att["p99_shares"]["queue_wait"], 4),
        "seeded_queue_share_p99": round(
            s_att["p99_shares"]["queue_wait"], 4),
        "healthy_dominant": at_att["dominant_phase"],
        "seeded_dominant": s_att["dominant_phase"],
    }
    if not hit:
        findings.append({
            "severity": "error", "kind": "audit-detector-miss",
            "detail": f"seeded misconfiguration {name!r} was not flagged "
                      f"as {SEEDS[name]} "
                      f"(got {[f['kind'] for f in s_findings]})"})
    elif not localized:
        findings.append({
            "severity": "error", "kind": "audit-attribution-phase",
            "detail": f"pathway-attribution fired on {name!r} but did not "
                      f"name queue_wait as the dominant phase "
                      f"(seeded dominant: {s_att['dominant_phase']})"})
    if not token_identical:
        findings.append({
            "severity": "error", "kind": "audit-seed-divergence",
            "detail": f"seeded misconfiguration {name!r} changed the "
                      f"token stream — it must degrade the pathway, "
                      f"not the answer"})
    if (s_att["p99_shares"]["queue_wait"]
            <= at_att["p99_shares"]["queue_wait"]):
        findings.append({
            "severity": "error", "kind": "audit-seed-uncontrasted",
            "detail": "admission throttle did not inflate the p99 queue "
                      "share over the healthy run: the seed contrasts "
                      "nothing"})

    # ------------------------------------ request-lifecycle probe: the
    # cancel and sampling pathways must be *visible* in the audit trace
    # (submit carries the sampling policy; cancel releases every page)
    life_audit = RunAudit(_ctx(cfg))
    life_audit.registry.register(ttft_rule)
    life_eng = PagedServeEngine(model, params, slots=slots, max_len=max_len,
                                block_size=block, chunk=chunk,
                                tracer=life_audit.tracer)
    life_reqs = make()
    for r in life_reqs:
        r.sampling = sampled
    handles = [life_eng.submit(r) for r in life_reqs]
    life_eng.step()                      # victims are mid-prefill here
    handles[0].cancel()                  # running (prefill or decode)
    handles[-1].cancel()                 # still waiting (n_req > slots)
    life_eng.drain()
    findings.extend(life_audit.evaluate(engine_report=life_eng.report()))
    counts = life_audit.tracer.summary()["counts"]
    sampled_submits = sum(
        1 for e in life_audit.tracer.events("submit")
        if e.data.get("sampling", "greedy") != "greedy")
    lifecycle = {
        "cancelled": life_eng.stats.cancelled,
        "served": life_eng.stats.served,
        "cancel_events": counts.get("cancel", 0),
        "first_token_events": counts.get("first-token", 0),
        "sampled_submits": sampled_submits,
        "pages_in_use_after": life_eng.alloc.in_use,
        "prefix_entries": len(life_eng.prefix),
    }
    if counts.get("cancel", 0) < 2 or sampled_submits < n_req:
        findings.append({
            "severity": "error", "kind": "audit-lifecycle-trace",
            "detail": f"request-lifecycle events missing from the trace: "
                      f"{lifecycle}"})
    if life_eng.alloc.in_use != len(life_eng.prefix):
        findings.append({
            "severity": "error", "kind": "audit-cancel-leak",
            "detail": f"cancellation leaked pages: {life_eng.alloc.in_use} "
                      f"in use vs {len(life_eng.prefix)} prefix-cache "
                      f"registrations"})

    # --------------------------------- perf ledger (opt-in, like every
    # serving benchmark: only a caller that names a ledger dir gates on
    # baselines, so bare benchmark runs never write repo-root state)
    metrics = {
        **paged_counter_metrics(rep),
        "tokens_per_s": round(rep["tokens_out"] / max(wall, 1e-9), 1),
    }
    ledger_out = None
    if ledger_dir is not None:
        ledger = Ledger(ledger_dir)
        # shared deterministic counter bands + this benchmark's
        # wall-clock throughput (tracked, not gated: CPU CI noise)
        specs = (PAGED_COUNTER_SPECS
                 + [MetricSpec("tokens_per_s", gate=False)])
        # smoke and full traces have different shapes: separate baselines
        bench_key = f"audit_pathways_{'smoke' if smoke else 'full'}"
        ledger_res = ledger.compare(bench_key, metrics, specs,
                                    update_baseline=update_baseline)
        findings.extend(ledger_res.findings)
        ledger_out = {"baseline_written": ledger_res.baseline_written,
                      "deltas": ledger_res.deltas,
                      "path": str(ledger.path(bench_key))}

    return {
        "bench": "audit_pathways",
        "arch": cfg.name,
        "mode": "smoke" if smoke else "full",
        "oracle_ok": all(oracle_ok.values()),
        "oracle_modes": oracle_ok,
        "ttft_bound_ticks": round(ttft_bound, 2),
        "healthy_findings": healthy,
        "detections": detections,
        "detected_all": all(d["detected"] for d in detections.values()),
        "lifecycle": lifecycle,
        "trace": audit.tracer.summary(),
        "metrics": metrics,
        "ledger": ledger_out,
        "findings": findings,
    }


def run():
    """benchmarks.run CSV protocol."""
    res = bench(smoke=True)
    n_err = sum(1 for f in res["findings"] if f["severity"] == "error")
    if n_err:
        raise RuntimeError(f"audit_pathways: {n_err} error finding(s): "
                           + "; ".join(f["detail"] for f in res["findings"]
                                       if f["severity"] == "error"))
    yield {"name": "audit_pathways.detectors",
           "us_per_call": 0.0,
           "derived": (f"detected_all={res['detected_all']} "
                       f"oracle_ok={res['oracle_ok']}")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ledger-dir", default=None,
                    help="BENCH_*.json directory; omit to skip the ledger")
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args()
    print(json.dumps(bench(args.arch, smoke=args.smoke, seed=args.seed,
                           ledger_dir=args.ledger_dir,
                           update_baseline=args.update_baseline)))


if __name__ == "__main__":
    main()
