"""Integration tests: sharded-vs-single-device parity (the dual-environment
methodology applied to the framework itself), end-to-end train/resume, the
serving engine, and launch-script emission.  Multi-device cases run in
subprocesses so the main test process keeps the real single-device view."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _sub(code: str) -> subprocess.CompletedProcess:
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, cwd="/root/repo")


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "qwen3-moe-30b-a3b",
                                  "mamba2-2.7b", "zamba2-2.7b",
                                  "whisper-medium", "llama-3.2-vision-11b"])
def test_sharded_loss_parity(arch):
    """Loss under the production rule set on a (2,2,2) pod×data×model mesh
    must equal the single-device loss (the paper's native == container)."""
    out = _sub(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax
        from repro.configs import ALL_ARCHS, reduced, ShapeConfig
        from repro.configs.base import RunConfig, TrainConfig
        from repro.launch.bind import batch_shardings, param_shardings
        from repro.models import build
        from repro.parallel import bind, rules_for
        from repro.launch.mesh import mesh_of
        mesh = mesh_of((2, 2, 2), ("pod", "data", "model"))
        cfg = reduced(ALL_ARCHS["{arch}"])
        model = build(cfg)
        shape = ShapeConfig("t", "train", 32, 4)
        key = jax.random.PRNGKey(0)
        params = model.init_params(key)
        batch = model.sample_batch(shape, key)
        ref, _ = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
        run = RunConfig(model=cfg, shape=shape)
        with bind(mesh, rules_for(run)):
            ps = jax.device_put(params, param_shardings(model, mesh))
            bs = jax.device_put(batch, batch_shardings(model, shape, mesh))
            sh, _ = jax.jit(lambda p, b: model.loss(p, b))(ps, bs)
        err = abs(float(sh) - float(ref))
        assert err < 2e-2, (float(ref), float(sh))
        print("PARITY", err)
    """)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PARITY" in out.stdout


def test_train_checkpoint_restart_bitexact(tmp_path):
    """Kill-and-restart must continue the loss curve exactly: train 8 steps
    in one run vs 4 + resume 4 (same data, same final loss)."""
    from repro.launch.train import train

    r_full = train("granite-moe-1b-a400m", steps=8, ckpt_every=4,
                   out_dir=str(tmp_path / "full"), seed=3, total_steps=8)
    r_half = train("granite-moe-1b-a400m", steps=4, ckpt_every=4,
                   out_dir=str(tmp_path / "resume"), seed=3, total_steps=8)
    r_res = train("granite-moe-1b-a400m", steps=8, ckpt_every=4,
                  out_dir=str(tmp_path / "resume"), resume=True, seed=3,
                  total_steps=8)
    assert r_res["last_loss"] == pytest.approx(r_full["last_loss"], rel=1e-4)
    assert r_full["loss_decreased"]


def test_serve_engine_continuous_batching():
    from repro.launch.serve import serve

    res = serve("granite-moe-1b-a400m", n_requests=5, slots=2, max_len=64,
                max_new=8)
    assert res["served"] == 5
    assert res["tokens_out"] >= 5 * 8 - 5
    assert 1.0 <= res["mean_batch_occupancy"] <= 2.0


def test_paged_engine_matches_contiguous_oracle():
    """The paged engine's correctness proof: on a batch of
    overlapping-prefix prompts, the paged path (prefix-cache reuse +
    chunked prefill + paged admission) must produce exactly the greedy
    token streams of the seed contiguous engine, asserted through a
    core.verify dual-environment verdict — the same methodology the paper
    uses for native-vs-container parity."""
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import PagedServeEngine, Request, compare_engines

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, size=18).tolist()
    tails = [rng.integers(0, cfg.vocab_size, size=4 + i).tolist()
             for i in range(4)]

    def make():
        return [Request(rid=i, prompt=shared + tails[i], max_new=8)
                for i in range(4)]

    report = compare_engines(model, params, make, slots=2, max_len=64,
                             block_size=8, chunk=4)
    assert report.ok, report.summary()
    [verdict] = report.verdicts
    assert verdict.kind == "numeric" and verdict.measured == 0.0

    # the parity must come with actual page reuse, not a degenerate cache
    eng = PagedServeEngine(model, params, slots=2, max_len=64,
                           block_size=8, chunk=4)
    eng.run(make())
    assert eng.pstats.cached_tokens > 0
    assert eng.report()["prefix_hit_rate"] > 0
    eng.alloc.check()
    # and the production default is the page-table kernel pathway: KV
    # lives in the device page pool, no dense working cache, no host pool
    assert eng.report()["kernel"] == "paged"
    assert eng.pool is None and "paged" in eng.cache


def test_kernel_and_gather_pathways_both_match_oracle():
    """The oracle holds with the KV pathway pinned explicitly either way
    (engine_kwargs passthrough): the Pallas page-table mode and the dense
    gather fallback each reproduce the contiguous streams, greedy and
    sampled — the ISSUE's end-to-end kernel-enabled oracle."""
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve import SamplingParams
    from repro.serve.engine import Request, compare_engines

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab_size, size=16).tolist()
    tails = [rng.integers(0, cfg.vocab_size, size=3 + i).tolist()
             for i in range(4)]

    def make():
        return [Request(rid=i, prompt=shared + tails[i], max_new=6)
                for i in range(4)]

    sampled = SamplingParams(temperature=0.8, top_k=16, top_p=0.9, seed=2)
    for kernel in ("paged", "gather"):
        for sp in (None, sampled):
            report = compare_engines(
                model, params, make, slots=2, max_len=64, block_size=8,
                chunk=4, sampling=sp,
                engine_kwargs={"paged": {"kernel": kernel}})
            assert report.ok, (kernel, sp, report.summary())


def test_decode_matches_prefill_continuation():
    """Greedy continuation via decode_step must match re-running prefill
    over the extended sequence (cache correctness, all families with
    attention caches rely on the same path — dense covers it)."""
    from repro.configs import ALL_ARCHS, reduced, ShapeConfig
    from repro.models import build

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    s = 12
    batch = model.sample_batch(ShapeConfig("p", "prefill", s, 2), key)

    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=s + 4))(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    dec_logits, _ = jax.jit(model.decode_step)(
        params, cache, tok, jnp.full((2,), s, jnp.int32))

    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    logits2, _ = jax.jit(lambda p, b: model.prefill(p, b))(params, ext)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(logits2, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_slurm_script_emission(tmp_path):
    from repro.launch.slurm import emit_all, emit_sbatch

    p = emit_sbatch("phi3-mini-3.8b", "train_4k", nodes=64,
                    container_image="esd.sif", out_dir=tmp_path)
    text = p.read_text()
    assert "apptainer exec --nv esd.sif" in text
    assert "REPRO_COORD_PORT" in text
    assert "--nodes=64" in text

    paths = emit_all(out_dir=tmp_path)
    assert len(paths) == 32  # every applicable assignment cell


def test_dryrun_cell_smoke_via_subprocess():
    """One real dry-run cell end to end through the CLI (production mesh,
    512 placeholder devices, multi-pod)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-moe-1b-a400m", "--shape", "decode_32k", "--multi-pod",
         "--out", "/tmp/dryrun_pytest"],
        capture_output=True, text=True, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-2000:])
    assert "[ok   ]" in out.stdout
    rec = json.loads(next(Path("/tmp/dryrun_pytest").glob("*.json")).read_text())
    assert rec["mesh"] == "2x16x16"
    assert rec["collectives"]["total_moved_bytes"] > 0
    assert rec["hlo_cost"]["dot_flops"] > 0
