"""Runtime audit pipeline: tracer invariants, expectation-registry
checks over fabricated evidence, perf-ledger baseline/compare semantics,
and the end-to-end proof that seeded pathway misconfigurations are
caught while the dual-environment oracle stays green."""
import jax
import numpy as np
import pytest

from repro.audit import (DEFAULT_REGISTRY, AuditContext, Evidence,
                         ExpectationRegistry, ExpectedSignature, Ledger,
                         MetricSpec, RunAudit, Rule, Tracer)
from repro.audit.trace import NULL_TRACER
from repro.core.inspector import CollectiveOp, TransportReport


# ---------------------------------------------------------------- tracer


def test_tracer_ring_overflow_keeps_exact_counts():
    tr = Tracer(capacity=8, clock=lambda: 0.0)
    for i in range(30):
        tr.emit("tick", i=i)
    tr.emit("other")
    assert len(tr.events()) == 8               # ring bounded
    assert tr.count("tick") == 30              # counts exact
    assert tr.count("other") == 1
    assert tr.dropped == 23
    assert tr.events()[-1].kind == "other"
    assert tr.last("tick").data["i"] == 29
    s = tr.summary()
    assert s["emitted"] == 31 and s["retained"] == 8
    assert s["counts"] == {"tick": 30, "other": 1}


def test_tracer_span_measures_and_attaches_results():
    tr = Tracer()
    with tr.span("work", step=3) as ev:
        ev["loss"] = 1.5
    [e] = tr.events("work")
    assert e.data["step"] == 3 and e.data["loss"] == 1.5
    assert e.data["dt_s"] >= 0


def test_tracer_payload_may_shadow_reserved_names():
    """Event payloads can carry their own ``kind`` (emit's first arg is
    positional-only) and span bodies can attach keys colliding with span
    kwargs — the body wins, ``dt_s`` always wins."""
    tr = Tracer()
    tr.emit("step", kind="chunk")
    assert tr.events("step")[0].data["kind"] == "chunk"
    with tr.span("work", loss=0.0, dt_s="shadowed") as ev:
        ev["loss"] = 2.5
    [e] = tr.events("work")
    assert e.data["loss"] == 2.5
    assert isinstance(e.data["dt_s"], float)


def test_tracer_injected_clock_is_deterministic():
    t = {"now": 0.0}
    tr = Tracer(clock=lambda: t["now"])
    tr.emit("a")
    t["now"] = 5.0
    tr.emit("b")
    assert [e.t for e in tr.events()] == [0.0, 5.0]


def test_null_tracer_records_nothing():
    NULL_TRACER.emit("x", a=1)
    with NULL_TRACER.span("y"):
        pass
    assert NULL_TRACER.events() == []
    assert NULL_TRACER.count("x") == 0
    assert not NULL_TRACER.enabled


def test_tracer_subscriber_sees_every_event_past_overflow():
    """The live-metrics feed contract: a subscriber observes the complete
    stream even after the bounded ring has evicted the early events."""
    tr = Tracer(capacity=4, clock=lambda: 0.0)
    seen = []
    fn = tr.subscribe(seen.append)
    for i in range(25):
        tr.emit("tick", i=i)
    assert len(tr.events()) == 4 and tr.dropped == 21  # ring wrapped...
    assert len(seen) == 25                             # ...subscriber exact
    assert [e.data["i"] for e in seen] == list(range(25))
    assert [e.seq for e in seen] == list(range(25))
    tr.unsubscribe(fn)
    tr.emit("tick", i=99)
    assert len(seen) == 25                 # unsubscribed: no more delivery
    assert tr.count("tick") == 26          # counts still exact


def test_tracer_spans_nest_across_overflow():
    """Span closing events land in order (inner first) with exact counts
    even when the events emitted inside the spans wrap the ring."""
    tr = Tracer(capacity=3)
    seen = []
    tr.subscribe(seen.append)
    with tr.span("outer"):
        with tr.span("inner"):
            for i in range(10):
                tr.emit("tick", i=i)
    assert tr.count("tick") == 10
    assert tr.count("inner") == 1 and tr.count("outer") == 1
    assert tr.dropped == 12 - 3
    # the ring retains only the tail, but the subscriber saw everything
    assert [e.kind for e in seen[-2:]] == ["inner", "outer"]
    assert sum(e.kind == "tick" for e in seen) == 10
    # the retained tail ends with the two span closings
    assert [e.kind for e in tr.events()[-2:]] == ["inner", "outer"]


def test_emitted_kinds_are_declared_in_known_kinds():
    """Emit-kind lint: every ``tracer.emit("...")`` / ``tracer.span("...")``
    string literal in ``src/``, ``benchmarks/``, and ``scripts/`` must
    appear in ``KNOWN_KINDS`` — a typo'd kind cannot silently create an
    event stream nothing subscribes to, no matter which layer emits it."""
    import ast
    from pathlib import Path

    from repro.audit.trace import KNOWN_KINDS

    repo = Path(__file__).resolve().parent.parent
    roots = [repo / "src", repo / "benchmarks", repo / "scripts"]

    def literal_kinds(node):
        """String constants reachable as the call's kind argument
        (plain literals and both arms of conditional expressions)."""
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return [node.value]
        if isinstance(node, ast.IfExp):
            return literal_kinds(node.body) + literal_kinds(node.orelse)
        return []

    found = {}
    for root in roots:
        for path in sorted(root.rglob("*.py")):
            tree = ast.parse(path.read_text(), filename=str(path))
            for node in ast.walk(tree):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in ("emit", "span") and node.args):
                    for kind in literal_kinds(node.args[0]):
                        found.setdefault(kind, []).append(
                            f"{path.relative_to(repo)}:{node.lineno}")
    undeclared = {k: v for k, v in found.items() if k not in KNOWN_KINDS}
    assert not undeclared, (
        f"emit/span kinds missing from KNOWN_KINDS: {undeclared}")
    # the lint must not be vacuous: the instrumented layers are present,
    # including the cluster router's route events
    assert len(found) >= 15, sorted(found)
    assert "route" in found


# ---------------------------------------------------------- expectations


def _serve_ctx(**kw):
    defaults = dict(workload="serve", family="dense", arch="t",
                    shared_prefix=True)
    defaults.update(kw)
    return AuditContext(**defaults)


def _paged_report(**over):
    rep = {"engine": "paged", "block_size": 8, "prefix_cache": True,
           "prefix_hit_rate": 0.5}
    rep.update(over)
    return rep


def _kinds(findings):
    return {f["kind"] for f in findings}


def test_registry_matching_on_family_workload_mesh():
    reg = ExpectationRegistry([
        Rule("a", ExpectedSignature(), families=("dense",),
             workloads=("serve",)),
        Rule("b", ExpectedSignature(), families=("ssm",)),
        Rule("c", ExpectedSignature(), min_devices=8),
    ])
    assert [r.name for r in reg.match(_serve_ctx())] == ["a"]
    assert [r.name for r in reg.match(_serve_ctx(family="ssm"))] == ["b"]
    big = _serve_ctx(mesh=(2, 2, 2))
    assert "c" in [r.name for r in reg.match(big)]
    # "bench:<name>" workloads match rules declared for "bench"
    reg2 = ExpectationRegistry(
        [Rule("d", ExpectedSignature(), workloads=("bench",))])
    assert reg2.match(_serve_ctx(workload="bench:audit_pathways"))


def test_clean_paged_evidence_yields_no_findings():
    ev = Evidence(engine_report=_paged_report())
    assert DEFAULT_REGISTRY.evaluate(_serve_ctx(), ev) == []


def test_engine_selection_mismatch_is_error():
    ev = Evidence(engine_report={"engine": "contiguous"})
    fs = DEFAULT_REGISTRY.evaluate(_serve_ctx(), ev)
    assert _kinds(fs) == {"pathway-engine-selection"}
    assert all(f["severity"] == "error" for f in fs)
    # ...and the inverse: paged where contiguous is the correct pathway
    fs = DEFAULT_REGISTRY.evaluate(
        _serve_ctx(family="ssm"), Evidence(engine_report=_paged_report()))
    assert "pathway-engine-selection" in _kinds(fs)


def test_shrunk_page_size_is_flagged():
    ev = Evidence(engine_report=_paged_report(block_size=2))
    fs = DEFAULT_REGISTRY.evaluate(_serve_ctx(), ev)
    assert "pathway-page-geometry" in _kinds(fs)


def test_prefix_cache_disabled_or_ineffective_is_flagged():
    fs = DEFAULT_REGISTRY.evaluate(
        _serve_ctx(), Evidence(engine_report=_paged_report(
            prefix_cache=False)))
    assert "pathway-prefix-cache" in _kinds(fs)
    fs = DEFAULT_REGISTRY.evaluate(
        _serve_ctx(), Evidence(engine_report=_paged_report(
            prefix_hit_rate=0.0)))
    assert "pathway-prefix-cache" in _kinds(fs)
    # not an expectation without prompt sharing
    fs = DEFAULT_REGISTRY.evaluate(
        _serve_ctx(shared_prefix=False),
        Evidence(engine_report=_paged_report(prefix_hit_rate=0.0)))
    assert "pathway-prefix-cache" not in _kinds(fs)


def test_recompilation_in_hot_loop_is_flagged():
    tr = Tracer()
    tr.emit("engine-init", engine="paged", block_size=8, prefix_cache=True)
    for shape in ((2, 4), (2, 5), (2, 6)):
        tr.emit("compile", fn="decode_chunk", reason="new-shapes",
                signature=shape)
    fs = DEFAULT_REGISTRY.evaluate(
        _serve_ctx(shared_prefix=False), Evidence(tracer=tr))
    assert "pathway-recompilation" in _kinds(fs)


def test_p99_slo_rule_fires_on_tail_and_abstains_within_bound():
    """``pathway-slo``: the quantile expectations judge the population
    tail from the lifecycle trace — one pathological straggler out of
    many breaches a p99 bound the per-request max rule would also catch,
    but a *fleet-wide* bound set above the healthy p99 stays silent."""
    from repro.audit.expectations import nearest_rank

    def traced_run(ttfts):
        tr = Tracer(clock=lambda: 0.0)
        for rid, ttft in enumerate(ttfts):
            tr.emit("submit", rid=rid, arrival=0.0)
            tr.emit("first-token", rid=rid, tick=float(ttft),
                    ttft_ticks=float(ttft))
            # 5 tokens over 8 ticks after the first: gap 2.0 each
            tr.emit("finish", rid=rid, tick=float(ttft) + 8.0, tokens_out=5)
        return Evidence(tracer=tr)

    ttfts = [2.0] * 19 + [40.0]            # p99 == the straggler
    assert nearest_rank(ttfts, 0.99) == 40.0

    def reg(**sig):
        return ExpectationRegistry([Rule(
            "slo", ExpectedSignature(**sig), workloads=("serve",))])

    fs = reg(p99_ttft_ticks=10.0).evaluate(_serve_ctx(), traced_run(ttfts))
    assert _kinds(fs) == {"pathway-slo"}
    assert all(f["severity"] == "error" for f in fs)
    # bound above the tail: clean
    assert reg(p99_ttft_ticks=50.0).evaluate(
        _serve_ctx(), traced_run(ttfts)) == []
    # decode-gap SLO over the same evidence (every gap is 2.0 ticks)
    assert reg(p99_decode_gap_ticks=1.5).evaluate(
        _serve_ctx(), traced_run(ttfts)) != []
    assert reg(p99_decode_gap_ticks=2.0).evaluate(
        _serve_ctx(), traced_run(ttfts)) == []
    # no lifecycle evidence -> the check is skipped, not failed
    assert reg(p99_ttft_ticks=1.0).evaluate(
        _serve_ctx(), Evidence(tracer=Tracer())) == []


def test_nearest_rank_is_the_ceil_rank_order_statistic():
    from repro.audit.expectations import nearest_rank

    assert nearest_rank([3.0, 1.0, 2.0], 0.5) == 2.0
    assert nearest_rank([3.0, 1.0, 2.0], 1.0) == 3.0
    assert nearest_rank([7.0], 0.99) == 7.0
    assert nearest_rank(list(range(100)), 0.99) == 98  # ceil(99) = 99th
    with pytest.raises(ValueError, match="empty"):
        nearest_rank([], 0.5)
    with pytest.raises(ValueError, match="quantile"):
        nearest_rank([1.0], 0.0)


def test_non_moe_train_must_not_emit_expert_dispatch():
    report = TransportReport(ops=[CollectiveOp(
        name="a2a", kind="all-to-all", payload_bytes=64, group_size=2,
        computation="main")])
    ctx = AuditContext(workload="train", family="dense", mesh=(2,))
    fs = DEFAULT_REGISTRY.evaluate(ctx, Evidence(transport=report))
    assert "pathway-collective-kind" in _kinds(fs)
    # the same op is the expected pathway for expert (moe) dispatch
    moe_ctx = AuditContext(workload="train", family="moe", mesh=(2,))
    fs = DEFAULT_REGISTRY.evaluate(moe_ctx, Evidence(transport=report))
    assert "pathway-collective-kind" not in _kinds(fs)


def test_transport_expectations_group_and_host_transfer():
    report = TransportReport(
        ops=[CollectiveOp(name="ar", kind="all-reduce", payload_bytes=1024,
                          group_size=16, computation="main")],
        findings=[{"severity": "warn", "kind": "host-transfer",
                   "detail": "outfeed in module"}])
    ctx = AuditContext(workload="train", family="dense", mesh=(2, 2, 2))
    fs = DEFAULT_REGISTRY.evaluate(ctx, Evidence(transport=report))
    kinds = _kinds(fs)
    assert "pathway-collective-group" in kinds     # 16 > 8 devices
    assert "pathway-host-transfer" in kinds
    assert all(f["severity"] == "error" for f in fs)


# ---------------------------------------------------------------- ledger


SPECS = [MetricSpec("tokens_per_s", higher_is_better=True, rel_tol=0.2),
         MetricSpec("ttft_s", higher_is_better=False, rel_tol=0.2),
         MetricSpec("wall_s", gate=False)]


def test_ledger_roundtrip_baseline_then_pass_then_regression(tmp_path):
    led = Ledger(tmp_path)
    m = {"tokens_per_s": 100.0, "ttft_s": 0.5, "wall_s": 2.0}

    first = led.compare("serve", m, SPECS)
    assert first.baseline_written and first.ok
    assert led.path("serve").exists()
    assert led.baseline("serve") == m

    again = led.compare("serve", dict(m), SPECS)   # unchanged re-run passes
    assert not again.baseline_written and again.ok
    assert all(d["status"] == "ok" for d in again.deltas.values())

    # ≥20% synthetic throughput regression fails the gate
    worse = led.compare("serve", {**m, "tokens_per_s": 79.0}, SPECS)
    assert not worse.ok
    [f] = [f for f in worse.findings if f["severity"] == "error"]
    assert f["kind"] == "perf-regression"
    assert worse.deltas["tokens_per_s"]["status"] == "regression"


def test_ledger_direction_and_ungated_metrics(tmp_path):
    led = Ledger(tmp_path)
    m = {"tokens_per_s": 100.0, "ttft_s": 0.5, "wall_s": 2.0}
    led.compare("b", m, SPECS)
    # latency rising 50% is a regression; wall_s is tracked but never gates
    res = led.compare("b", {**m, "ttft_s": 0.75, "wall_s": 99.0}, SPECS)
    assert not res.ok
    assert res.deltas["ttft_s"]["status"] == "regression"
    assert res.deltas["wall_s"]["status"] == "ok"
    # improvements are info findings, not errors
    res = led.compare("b", {**m, "tokens_per_s": 150.0}, SPECS)
    assert res.ok
    assert any(f["kind"] == "perf-improvement" for f in res.findings)


def test_ledger_update_baseline_and_new_metrics(tmp_path):
    led = Ledger(tmp_path)
    led.compare("b", {"x": 10.0}, [MetricSpec("x")])
    # a metric the baseline has never seen is adopted, not judged
    res = led.compare("b", {"x": 10.0, "y": 1.0}, [MetricSpec("x")])
    assert res.ok and led.baseline("b")["y"] == 1.0
    res = led.compare("b", {"x": 5.0}, [MetricSpec("x")],
                      update_baseline=True)
    assert res.baseline_written and led.baseline("b")["x"] == 5.0
    res = led.compare("b", {"x": 5.0}, [MetricSpec("x")])
    assert res.ok


def test_ledger_corrupt_file_rewrites_baseline(tmp_path):
    led = Ledger(tmp_path)
    led.compare("b", {"x": 1.0}, [MetricSpec("x")])
    led.path("b").write_text("{not json")
    res = led.compare("b", {"x": 99.0}, [MetricSpec("x")])
    assert res.baseline_written and res.ok


def test_ledger_history_is_bounded(tmp_path):
    from repro.audit.ledger import HISTORY_KEEP
    led = Ledger(tmp_path)
    for i in range(HISTORY_KEEP + 9):
        led.compare("b", {"x": 1.0}, [MetricSpec("x")])
    rec = led.load("b")
    assert len(rec["history"]) == HISTORY_KEEP


def test_ledger_orphan_audit_flags_unowned_bench_files(tmp_path):
    """``audit_owned``: a BENCH file whose benchmark is not registered is
    an error — a baseline nobody maintains silently attests metrics
    nothing measures."""
    led = Ledger(tmp_path)
    led.compare("serve_throughput_smoke", {"x": 1.0}, [MetricSpec("x")])
    assert led.audit_owned(["serve_throughput_smoke"]) == []

    # a stray ledger from a deleted benchmark
    led.compare("serve_retired_smoke", {"y": 2.0}, [MetricSpec("y")])
    [f] = led.audit_owned(["serve_throughput_smoke"])
    assert f["kind"] == "ledger-orphan" and f["severity"] == "error"
    assert "serve_retired_smoke" in f["detail"]

    # unparseable files are judged by filename, not skipped
    (tmp_path / "BENCH_mystery.json").write_text("{not json")
    kinds = [f["kind"] for f in led.audit_owned(["serve_throughput_smoke",
                                                 "serve_retired_smoke"])]
    assert kinds == ["ledger-orphan"]


def test_smoke_all_gate_fails_on_orphan_ledger(tmp_path):
    """The harness-level proof: ``scripts/smoke_all.py``'s owned-key set
    plus ``Diagnostics.gate()`` turns an unowned BENCH file into a
    failing gate."""
    import importlib.util
    import os

    from repro.core.diagnostics import Diagnostics

    spec = importlib.util.spec_from_file_location(
        "smoke_all", os.path.join(os.path.dirname(__file__), os.pardir,
                                  "scripts", "smoke_all.py"))
    smoke_all = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(smoke_all)

    owned = smoke_all.owned_ledger_keys()
    assert {"serve_throughput_smoke", "audit_pathways_full",
            "serve_workloads_smoke"} <= set(owned)

    led = Ledger(tmp_path)
    for key in owned:                       # everything owned: gate passes
        led.compare(key, {"x": 1.0}, [MetricSpec("x")])
    diag = Diagnostics()
    diag.extend(led.audit_owned(owned), source="ledger-integrity")
    assert diag.gate()

    led.compare("serve_retired_smoke", {"y": 1.0}, [MetricSpec("y")])
    diag = Diagnostics()
    diag.extend(led.audit_owned(owned), source="ledger-integrity")
    assert not diag.gate()


def test_ledger_rolling_median_over_history(tmp_path):
    led = Ledger(tmp_path)
    assert led.rolling_median("b", "wall_s") is None    # no ledger at all
    for v in [10.0, 30.0, 20.0]:
        led.compare("b", {"x": 1.0, "wall_s": v},
                    [MetricSpec("x"), MetricSpec("wall_s", gate=False)])
    trend = led.rolling_median("b", "wall_s")
    assert trend == {"median": 20.0, "n": 3, "latest": 20.0}
    # even-length window averages the middle pair
    led.compare("b", {"x": 1.0, "wall_s": 100.0},
                [MetricSpec("x"), MetricSpec("wall_s", gate=False)])
    assert led.rolling_median("b", "wall_s")["median"] == 25.0
    # the window slides: only the most recent entries count
    assert led.rolling_median("b", "wall_s", window=2) == {
        "median": 60.0, "n": 2, "latest": 100.0}
    # a metric history never carried -> None, not a crash
    assert led.rolling_median("b", "nope") is None


# ------------------------------------------------------- compile watcher


def test_compile_watcher_counts_shape_cache_misses():
    from repro.models.decode import CompileWatcher

    fired = []
    fn = jax.jit(lambda x: x + 1)
    w = CompileWatcher(fn, "step",
                       on_compile=lambda *a: fired.append(a))
    import jax.numpy as jnp
    w(jnp.zeros((2, 4)))
    w(jnp.zeros((2, 4)))           # same shapes: no new compile
    assert w.compiles == 1 and w.calls == 2
    w(jnp.zeros((2, 8)))           # new shapes: a miss
    assert w.compiles == 2
    assert fired[0][0] == "step" and fired[0][1] == "new-shapes"


# ------------------------------------------- end-to-end seeded misconfigs


@pytest.mark.slow
def test_seeded_misconfigurations_detected_while_oracle_green():
    """The acceptance proof: each seeded misconfiguration (contiguous
    fallback on a dense arch, shrunk page size, disabled prefix cache)
    leaves the greedy token streams identical to the healthy run —
    ``compare_engines`` stays green — yet the audit flags each as an
    error-severity pathway finding."""
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import (PagedServeEngine, Request, ServeEngine,
                                    compare_engines, token_matrix)

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, size=16).tolist()
    tails = [rng.integers(0, cfg.vocab_size, size=3 + i).tolist()
             for i in range(4)]

    def make():
        return [Request(rid=i, prompt=prefix + tails[i], max_new=4)
                for i in range(4)]

    ctx = AuditContext(workload="serve", family=cfg.family, arch=cfg.name,
                       shared_prefix=True)

    # oracle: paged == contiguous on this trace
    assert compare_engines(model, params, make, slots=2, max_len=48,
                           block_size=8, chunk=4).ok

    # healthy run: audit is clean and page reuse actually happened
    audit = RunAudit(ctx)
    eng = PagedServeEngine(model, params, slots=2, max_len=48, block_size=8,
                           chunk=4, tracer=audit.tracer)
    healthy = token_matrix(eng.run(make()), 4, 4)
    assert eng.pstats.cached_tokens > 0
    assert audit.evaluate(engine_report=eng.report()) == []

    def contiguous(tr):
        return ServeEngine(model, params, slots=2, max_len=48, tracer=tr)

    def shrunk(tr):
        return PagedServeEngine(model, params, slots=2, max_len=48,
                                block_size=2, chunk=4, tracer=tr)

    def no_cache(tr):
        return PagedServeEngine(model, params, slots=2, max_len=48,
                                block_size=8, chunk=4,
                                use_prefix_cache=False, tracer=tr)

    seeds = {"pathway-engine-selection": contiguous,
             "pathway-page-geometry": shrunk,
             "pathway-prefix-cache": no_cache}
    for expected_kind, builder in seeds.items():
        s_audit = RunAudit(ctx)
        s_eng = builder(s_audit.tracer)
        tokens = token_matrix(s_eng.run(make()), 4, 4)
        assert (tokens == healthy).all(), expected_kind  # answer unchanged
        findings = s_audit.evaluate(engine_report=s_eng.report())
        hits = [f for f in findings if f["kind"] == expected_kind]
        assert hits and all(f["severity"] == "error" for f in hits), (
            expected_kind, findings)

    # degraded pathway is visible in the evidence, not just the verdict:
    # the cache-disabled run recomputed every prompt token
    assert s_eng.pstats.cached_tokens == 0
    assert s_eng.pstats.prefill_tokens > eng.pstats.prefill_tokens


@pytest.mark.slow
def test_sub_block_shared_prefix_does_not_false_positive():
    """A shared prefix shorter than one page cannot hit the cache (only
    full blocks register), so the serve launcher must not declare the
    workload shared-prefix — a healthy run stays gate-clean."""
    from repro.launch.serve import serve

    res = serve("deepseek-7b", n_requests=3, slots=2, max_len=48,
                max_new=4, shared_prefix=4, block_size=8)
    assert res["audit"]["gate_ok"], res["audit"]["findings"]


def test_empty_prompt_rejected_cleanly():
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import PagedServeEngine, Request, ServeEngine

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    paged = PagedServeEngine(model, params, slots=1, max_len=32,
                             block_size=4, chunk=4)
    with pytest.raises(ValueError, match="empty prompt"):
        paged.submit(Request(rid=0, prompt=[], max_new=4))
    contig = ServeEngine(model, params, slots=1, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        contig.run([Request(rid=0, prompt=[], max_new=4)])


@pytest.mark.slow
def test_paged_engine_trace_replays_deterministically():
    """Same trace (prompts, arrivals, priorities) → identical
    (kind, data) event stream, ``tick`` payloads included: the audit's
    replay-debugging contract.  (Wall-clock ``t`` stamps are excluded —
    the engine does not rebind a shared tracer's clock.)"""
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import PagedServeEngine, Request

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=8 + i).tolist()
               for i in range(3)]

    def run_traced():
        tr = Tracer()
        eng = PagedServeEngine(model, params, slots=2, max_len=48,
                               block_size=4, chunk=4, tracer=tr)
        eng.run([Request(rid=i, prompt=list(p), max_new=4)
                 for i, p in enumerate(prompts)],
                arrivals=[0.0, 0.0, 2.0])
        return [(e.kind, tuple(sorted(e.data.items())))
                for e in tr.events() if e.kind != "compile"]

    assert run_traced() == run_traced()
