"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the real single
CPU device; only launch/dryrun.py creates the 512 placeholder devices."""
import jax
import pytest

from repro.configs import ALL_ARCHS, reduced
from repro.configs.base import ShapeConfig


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def smoke_shape():
    return ShapeConfig("smoke", "train", 32, 2)


def smoke_cfg(name: str):
    return reduced(ALL_ARCHS[name])
