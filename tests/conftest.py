"""Shared fixtures + a hypothesis fallback so property tests always run.

NOTE: no XLA_FLAGS here — tests run on the real single CPU device; only
launch/dryrun.py creates the 512 placeholder devices.

The property-test modules guard their ``hypothesis`` import and fall back
to the tiny deterministic property loop below (``given``/``settings``/
``st``), so the invariant suites collect and run with or without the
dependency installed — hypothesis shrinks better, but the invariants are
always exercised.
"""
import functools
import inspect
import zlib

import jax
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, reduced
from repro.configs.base import ShapeConfig


# ----------------------------------------------------- property-loop shim


class _Sampler:
    """A hypothesis-strategy stand-in: draws one value from an rng."""

    def __init__(self, draw):
        self.draw = draw


class _StFallback:
    """Subset of ``hypothesis.strategies`` the suites use."""

    @staticmethod
    def integers(lo, hi):
        return _Sampler(lambda rng: int(rng.integers(lo, hi + 1)))

    @staticmethod
    def floats(lo, hi):
        return _Sampler(lambda rng: float(rng.uniform(lo, hi)))

    @staticmethod
    def sampled_from(items):
        seq = list(items)
        return _Sampler(lambda rng: seq[int(rng.integers(0, len(seq)))])


st = _StFallback()


def settings(max_examples=20, **_ignored):
    """Fallback ``hypothesis.settings``: records the example budget."""
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*samplers):
    """Fallback ``hypothesis.given``: a deterministic random property loop.
    The rng is seeded from the test name (stable across runs/processes);
    failures report the drawn arguments via the assertion traceback."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = (getattr(wrapper, "_max_examples", None)
                 or getattr(fn, "_max_examples", None) or 20)
            rng = np.random.default_rng(
                zlib.crc32(fn.__name__.encode("utf-8")))
            for _ in range(n):
                fn(*args, *[s.draw(rng) for s in samplers], **kwargs)
        # pytest must not see the property arguments as fixtures
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


@pytest.fixture(scope="session", autouse=True)
def _kernels_interpret_off_accelerator():
    """Force Pallas kernels into interpret mode when no accelerator is
    attached, so the ``kernel``-marked parity suites (and any test that
    forces the paged-attention kernel onto the serving path) run the
    real kernel bodies on CPU CI instead of failing to lower Mosaic."""
    from repro.kernels import ops
    prev = ops.FORCE_INTERPRET
    if jax.default_backend() != "tpu":
        ops.FORCE_INTERPRET = True
    yield
    ops.FORCE_INTERPRET = prev


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def smoke_shape():
    return ShapeConfig("smoke", "train", 32, 2)


def smoke_cfg(name: str):
    return reduced(ALL_ARCHS[name])
