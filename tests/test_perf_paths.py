"""Regression tests for the §Perf optimizations: the optimized pathways
must (a) stay numerically correct and (b) actually move fewer bytes than
the variants they replaced — asserted via the inspector, which makes the
perf work un-regressable by CI (the paper's 'performance-verified' gate)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np


def _sub(code: str):
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_cp_prefix_math():
    """The cross-shard prefix must reproduce a sequential linear scan."""
    from repro.models.ssm import _cp_prefix

    rng = np.random.default_rng(0)
    tp, b, h, p, n = 4, 2, 3, 4, 5
    s_all = jnp.asarray(rng.standard_normal((tp, b, h, p, n)), jnp.float32)
    d_all = jnp.asarray(rng.uniform(0.1, 0.9, (tp, b, h)), jnp.float32)

    # sequential reference
    acc = np.zeros((b, h, p, n), np.float32)
    expect = []
    for j in range(tp):
        expect.append(acc.copy())
        acc = acc * np.asarray(d_all)[j][..., None, None] + np.asarray(s_all)[j]

    for i in range(tp):
        got, final = _cp_prefix(s_all, d_all, jnp.asarray(i))
        np.testing.assert_allclose(np.asarray(got), expect[i], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(final), acc, rtol=1e-6)


def test_sp_rules_move_fewer_bytes_than_no_sp():
    """train rules (explicit SP transitions) vs train_no_sp on the same
    model must not increase wire traffic, and the ssm cp path must beat
    the GSPMD-default by a wide margin (the §Perf iteration-1 result)."""
    out = _sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, json
        from repro.configs import ALL_ARCHS, reduced, ShapeConfig
        from repro.configs.base import RunConfig, TrainConfig
        from repro.core.inspector import parse_hlo
        from repro.launch.bind import abstract_cell
        from repro.models import build
        from repro.parallel import bind, rules_for
        import dataclasses

        from repro.launch.mesh import mesh_of
        mesh = mesh_of((2, 4), ("data", "model"))
        # scale matters: the GSPMD fallback replicates the per-chunk state
        # tensor (scales with B*S) while cp pays fixed weight/state-summary
        # gathers — the crossover needs a non-toy sequence length.
        cfg = dataclasses.replace(reduced(ALL_ARCHS["mamba2-2.7b"]),
                                  n_layers=2, ssd_chunk=16)
        model = build(cfg)
        shape = ShapeConfig("t", "train", 512, 8)

        def moved(rules):
            run = RunConfig(model=cfg, shape=shape, rules=rules,
                            train=TrainConfig(remat="full"))
            with bind(mesh, rules_for(run)):
                fn, args, shards, out_sh, donate = abstract_cell(model, run, mesh)
                hlo = jax.jit(fn, in_shardings=shards, out_shardings=out_sh,
                              donate_argnums=donate).lower(*args).compile().as_text()
            return parse_hlo(hlo, 8).total_moved_bytes

        opt = moved("train")
        base = moved("train_no_sp")
        print(json.dumps({"opt": opt, "base": base}))
    """)
    import json

    res = json.loads(out.strip().splitlines()[-1])
    # context-parallel SSD must move far fewer bytes than GSPMD's
    # state-replication fallback
    assert res["opt"] < 0.7 * res["base"], res


def test_decode_seq_sharded_cache_parity():
    """GQA arch with kv < tp (seq-sharded cache layout) must decode to the
    same logits sharded and unsharded."""
    out = _sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ALL_ARCHS, reduced, ShapeConfig
        from repro.configs.base import RunConfig
        from repro.launch.bind import (batch_shardings, cache_shardings,
                                       param_shardings)
        from repro.models import build
        from repro.parallel import bind, rules_for
        import dataclasses

        # kv=1 < tp=4 forces the seq-sharded cache layout
        cfg = dataclasses.replace(reduced(ALL_ARCHS["deepseek-coder-33b"]),
                                  n_kv_heads=1, n_heads=4)
        model = build(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init_params(key)
        s = 16
        pb = model.sample_batch(ShapeConfig("p", "prefill", s, 2), key)
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=s + 2))(params, pb)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        pos = jnp.full((2,), s, jnp.int32)
        ref, _ = jax.jit(model.decode_step)(params, cache, tok, pos)

        from repro.launch.mesh import mesh_of
        mesh = mesh_of((2, 4), ("data", "model"))
        drun = RunConfig(model=cfg,
                         shape=ShapeConfig("d", "decode", s + 2, 2),
                         rules="serve")
        with bind(mesh, rules_for(drun)):
            psh = param_shardings(model, mesh)
            csh = cache_shardings(model, mesh, 2, s + 2)
            got, _ = jax.jit(model.decode_step)(
                jax.device_put(params, psh), jax.device_put(cache, csh),
                tok, pos)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err < 5e-2, err
        print("DECODE PARITY", err)
    """)
    assert "DECODE PARITY" in out


def test_flash_attention_model_path_parity():
    """use_pallas=True routes dense attention through the flash kernel
    (interpret on CPU); loss must match the jnp path."""
    import dataclasses

    from repro.configs import ALL_ARCHS, reduced, ShapeConfig
    from repro.models import build

    cfg = dataclasses.replace(reduced(ALL_ARCHS["deepseek-7b"]), n_layers=2)
    key = jax.random.PRNGKey(0)
    m_ref = build(cfg, use_pallas=False)
    m_ker = build(cfg, use_pallas=True)
    params = m_ref.init_params(key)
    batch = m_ref.sample_batch(ShapeConfig("t", "train", 128, 2), key)
    l1, _ = jax.jit(lambda p, b: m_ref.loss(p, b))(params, batch)
    l2, _ = jax.jit(lambda p, b: m_ker.loss(p, b))(params, batch)
    assert abs(float(l1) - float(l2)) < 2e-2, (float(l1), float(l2))


def test_int8_gradient_compression_trains():
    """grad_compress='int8_ef' must still descend (the cross-pod DP
    bandwidth knob from DESIGN §9)."""
    from repro.configs import ALL_ARCHS, reduced, ShapeConfig
    from repro.configs.base import RunConfig, TrainConfig
    from repro.models import build
    from repro.train.step import init_train_state, make_train_step

    cfg = reduced(ALL_ARCHS["phi3-mini-3.8b"])
    model = build(cfg)
    shape = ShapeConfig("t", "train", 32, 4)
    run = RunConfig(model=cfg, shape=shape,
                    train=TrainConfig(learning_rate=3e-3, warmup_steps=1,
                                      grad_compress="int8_ef"))
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, run))
    batch = model.sample_batch(shape, jax.random.PRNGKey(1))
    losses = []
    for _ in range(6):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
