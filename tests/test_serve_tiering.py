"""KV memory tiering: host swap pool invariants, prefix-page spill and
page-in, swap-restore token exactness, the swap-vs-recompute cost model,
and randomized preempt/readmit/cancel/evict interleavings on both KV
pathways (satellite property suite)."""
import jax
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, reduced
from repro.models import build
from repro.serve.api import SamplingParams
from repro.serve.engine import PagedServeEngine, Request
from repro.serve.paging import (BlockAllocator, BlockAllocatorError,
                                HostSwapPool, PrefixCache, chain_hashes)
from repro.serve.scheduler import PREEMPTED, RUNNING, SwapCostModel


@pytest.fixture(scope="module")
def served():
    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


# ================================================== host swap pool units


def _rows(fill, shape=(2, 4, 1, 3)):
    return (np.full(shape, float(fill), np.float32),
            np.full(shape, -float(fill), np.float32))


def test_host_pool_roundtrip_refcounts_and_copy_semantics():
    pool = HostSwapPool(capacity=4, block_size=4)
    k, v = _rows(1)
    hid = pool.put(k, v)
    k[:] = 99.0                      # put copies: mutation must not leak in
    kk, vv = pool.get(hid)
    assert float(kk[0, 0, 0, 0]) == 1.0
    assert float(vv[0, 0, 0, 0]) == -1.0
    pool.incref(hid)
    pool.decref(hid)
    assert pool.in_use == 1 and pool.refcount(hid) == 1
    pool.decref(hid, swapped_in=True)
    assert pool.in_use == 0 and pool.refcount(hid) == 0
    assert pool.stats.swap_out_pages == 1
    assert pool.stats.swap_in_pages == 1
    assert pool.stats.dropped_pages == 0
    with pytest.raises(BlockAllocatorError):
        pool.get(hid)
    with pytest.raises(BlockAllocatorError):
        pool.decref(hid)
    with pytest.raises(BlockAllocatorError):
        pool.incref(hid)
    pool.check()


def test_host_pool_capacity_full_returns_none():
    pool = HostSwapPool(capacity=1, block_size=4)
    first = pool.put(*_rows(1))
    assert first is not None
    assert pool.put(*_rows(2)) is None       # graceful: caller recomputes
    pool.check()
    pool.decref(first)
    assert pool.put(*_rows(3)) is not None   # capacity freed by the drop
    assert pool.stats.dropped_pages == 1
    assert pool.stats.peak_in_use == 1
    pool.check()


def test_host_pool_ids_are_monotonic_never_reused():
    pool = HostSwapPool(capacity=2, block_size=4)
    a = pool.put(*_rows(1))
    pool.decref(a)
    b = pool.put(*_rows(2))
    assert b != a                # a stale id can never alias fresh storage
    pool.check()


# ================================================ prefix-cache spill units


def _spill_cache(num_blocks=4, block_size=2, capacity=8):
    """PrefixCache wired to fake spill hooks backed by a dict."""
    alloc = BlockAllocator(num_blocks, block_size)
    cache = PrefixCache(alloc)
    host: dict[int, int] = {}
    dropped: list[int] = []
    counter = iter(range(1000))

    def spill_out(bid):
        hid = next(counter)
        host[hid] = bid
        return hid

    def page_in(hid):
        if alloc.num_free == 0:
            return None
        assert hid in host
        return alloc.alloc()

    def drop(hid):
        dropped.append(hid)
        del host[hid]

    cache.attach_spill(spill_out=spill_out, page_in=page_in, drop=drop,
                       capacity=capacity)
    return alloc, cache, host, dropped


def test_prefix_spill_and_match_page_in_roundtrip():
    alloc, cache, host, dropped = _spill_cache()
    toks = [1, 2, 3, 4]
    h0, h1 = chain_hashes(toks, 2)
    b0, b1 = alloc.alloc(), alloc.alloc()
    cache.insert(h0, b0)
    cache.insert(h1, b1)
    alloc.decref(b0)
    alloc.decref(b1)                 # cache is now sole owner
    assert cache.evict(2) == 2
    assert cache.spilled == 2 and len(cache) == 0
    assert cache.stats.spills == 2 and len(host) == 2

    n, bids = cache.match(toks)      # pages both entries back in
    assert n == 4 and len(bids) == 2
    assert cache.stats.restores == 2
    assert cache.spilled == 0 and len(host) == 0 and len(dropped) == 2
    for bid in bids:
        alloc.decref(bid)
    alloc.check()


def test_prefix_spill_page_in_oom_stops_match_at_resident_prefix():
    alloc, cache, host, _ = _spill_cache(num_blocks=2)
    toks = [1, 2, 3, 4]
    h0, h1 = chain_hashes(toks, 2)
    b0, b1 = alloc.alloc(), alloc.alloc()
    cache.insert(h0, b0)
    cache.insert(h1, b1)
    alloc.decref(b0)
    alloc.decref(b1)
    assert cache.evict(2) == 2
    # burn every device page: page-in has nowhere to restore to
    pinned = [alloc.alloc() for _ in range(alloc.num_free)]
    n, bids = cache.match(toks)
    assert n == 0 and bids == []
    assert cache.spilled == 2 and len(host) == 2   # entries stay parked
    for bid in pinned:
        alloc.decref(bid)
    alloc.check()


def test_prefix_insert_drops_stale_spilled_duplicate():
    alloc, cache, host, dropped = _spill_cache()
    toks = [5, 6]
    (h,) = chain_hashes(toks, 2)
    b = alloc.alloc()
    cache.insert(h, b)
    alloc.decref(b)
    assert cache.evict(1) == 1 and cache.spilled == 1
    b2 = alloc.alloc()               # a slot re-registers the same chain
    cache.insert(h, b2)
    assert cache.spilled == 0 and len(dropped) == 1   # spill superseded
    alloc.decref(b2)
    alloc.check()


def test_prefix_spill_capacity_bound_drops_oldest():
    alloc, cache, host, dropped = _spill_cache(num_blocks=4, capacity=1)
    toks = [1, 2, 3, 4]
    h0, h1 = chain_hashes(toks, 2)
    b0, b1 = alloc.alloc(), alloc.alloc()
    cache.insert(h0, b0)
    cache.insert(h1, b1)
    alloc.decref(b0)
    alloc.decref(b1)
    assert cache.evict(2) == 2
    assert cache.spilled == 1        # capacity=1: oldest spill dropped
    assert len(dropped) == 1 and len(host) == 1
    alloc.check()


# ============================================== cost model + engine units


def test_swap_cost_model_prefers_recompute_for_tiny_victims():
    m = SwapCostModel()              # 2.0/page vs 1.0/token
    assert not m.prefer_swap(pages=1, tokens=1)    # restore 2.0 > redo 1.0
    assert m.prefer_swap(pages=1, tokens=2)        # tie goes to swap
    assert m.prefer_swap(pages=4, tokens=100)


def _preempt_once(model, params, kernel, *, sampling=None, **kw):
    """Tight single-slot engine: lo runs, hi preempts it, both finish.
    Returns (engine, lo_handle, hi_handle)."""
    rng = np.random.default_rng(11)
    lo_p = rng.integers(0, 50, 12).tolist()
    hi_p = rng.integers(50, 100, 8).tolist()
    eng = PagedServeEngine(model, params, slots=1, max_len=64, block_size=4,
                           num_blocks=10, chunk=4, kernel=kernel, **kw)
    lo = eng.submit(Request(rid=0, prompt=lo_p, max_new=16, priority=0,
                            sampling=sampling), arrival=0.0)
    for _ in range(4):
        eng.step()
    hi = eng.submit(Request(rid=1, prompt=hi_p, max_new=6, priority=5,
                            sampling=sampling))
    eng.drain()
    return eng, lo, hi


@pytest.mark.parametrize("kernel", ["paged", "gather"])
@pytest.mark.parametrize("sampled", [False, True])
def test_swap_restore_is_token_exact(served, kernel, sampled):
    _, model, params = served
    sp = (SamplingParams(temperature=0.7, top_k=16, top_p=0.95, seed=13)
          if sampled else None)
    # uninterrupted reference on an ample pool
    rng = np.random.default_rng(11)
    lo_p = rng.integers(0, 50, 12).tolist()
    hi_p = rng.integers(50, 100, 8).tolist()
    ref = PagedServeEngine(model, params, slots=2, max_len=64, block_size=4,
                           num_blocks=32, chunk=4, kernel=kernel)
    ref_out = {r.rid: list(r.out) for r in ref.run(
        [Request(rid=0, prompt=list(lo_p), max_new=16, sampling=sp),
         Request(rid=1, prompt=list(hi_p), max_new=6, sampling=sp)])}

    eng, lo, hi = _preempt_once(model, params, kernel, sampling=sp)
    rep = eng.report()
    assert rep["preemptions"] >= 1
    assert rep["swap_ins"] >= 1 and rep["restored_tokens"] > 0
    assert rep["recompute_tokens"] == 0
    assert rep["swap_restore_rate"] == 1.0
    assert lo.req.out == ref_out[0] and hi.req.out == ref_out[1]
    eng.alloc.check()
    eng.host.check()
    assert eng.host.in_use == eng.prefix.spilled   # no leaked swap records


@pytest.mark.parametrize("kernel", ["paged", "gather"])
def test_swap_disabled_recomputes_and_stays_exact(served, kernel):
    _, model, params = served
    ref_eng, ref_lo, ref_hi = _preempt_once(model, params, kernel)
    eng, lo, hi = _preempt_once(model, params, kernel, swap=False)
    rep = eng.report()
    assert rep["preemptions"] >= 1
    assert rep["swap_ins"] == 0 and rep["swap_outs"] == 0
    assert rep["restored_tokens"] == 0 and rep["recompute_tokens"] > 0
    assert rep["swap_restore_rate"] == 0.0
    assert eng.host.in_use == 0
    # recompute and restore produce the same streams
    assert lo.req.out == ref_lo.req.out and hi.req.out == ref_hi.req.out


def test_swap_cost_model_override_forces_recompute(served):
    _, model, params = served
    costly = SwapCostModel(swap_cost_per_page=1e9)
    eng, lo, hi = _preempt_once(model, params, "paged", swap_cost=costly)
    rep = eng.report()
    assert rep["preemptions"] >= 1
    assert rep["swap_outs"] >= 1     # pages were parked ...
    assert rep["swap_ins"] == 0      # ... but the model refused the restore
    assert rep["recompute_tokens"] > 0
    # the refused restore's host pages were dropped at readmission
    assert eng.host.in_use == eng.prefix.spilled
    eng.host.check()


def test_host_tier_full_falls_back_to_recompute(served):
    _, model, params = served
    eng, lo, hi = _preempt_once(model, params, "paged", host_blocks=0)
    rep = eng.report()
    assert rep["preemptions"] >= 1
    assert rep["swap_ins"] == 0 and rep["restored_tokens"] == 0
    assert rep["recompute_tokens"] > 0
    assert eng.host.in_use == 0
    eng.host.check()


def test_cancel_while_preempted_releases_host_pages(served):
    _, model, params = served
    rng = np.random.default_rng(11)
    lo_p = rng.integers(0, 50, 12).tolist()
    hi_p = rng.integers(50, 100, 8).tolist()
    eng = PagedServeEngine(model, params, slots=1, max_len=64, block_size=4,
                           num_blocks=10, chunk=4, kernel="paged")
    lo = eng.submit(Request(rid=0, prompt=lo_p, max_new=16, priority=0),
                    arrival=0.0)
    for _ in range(4):
        eng.step()
    hi = eng.submit(Request(rid=1, prompt=hi_p, max_new=6, priority=5))
    eng.step()
    assert lo.entry.state == PREEMPTED
    parked = eng.host.in_use - eng.prefix.spilled
    assert parked > 0                # the victim's pages sit in the tier
    assert lo.cancel()
    assert eng.host.in_use == eng.prefix.spilled   # released at cancel
    eng.drain()
    eng.alloc.check()
    eng.host.check()


# ====================================== satellite: generation-budget guard


@pytest.mark.parametrize("engine_cls", ["contiguous", "paged"])
def test_max_new_must_leave_room_for_the_prompt(served, engine_cls):
    from repro.serve.engine import ServeEngine
    _, model, params = served
    if engine_cls == "contiguous":
        eng = ServeEngine(model, params, slots=1, max_len=16)
    else:
        eng = PagedServeEngine(model, params, slots=1, max_len=16,
                               block_size=4, num_blocks=8, chunk=4)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=16))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(rid=1, prompt=[1, 2, 3], max_new=99))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(rid=2, prompt=[1, 2, 3], max_new=0))
    # the boundary case max_new == max_len - 1 is legal
    h = eng.submit(Request(rid=3, prompt=[1, 2, 3], max_new=15))
    eng.drain()
    assert len(h.req.out) == 15


# ================================= property suite: random interleavings


@pytest.mark.parametrize("kernel", ["paged", "gather"])
@pytest.mark.parametrize("seed", [0, 1])
def test_random_interleavings_match_uninterrupted_run(served, kernel, seed):
    """Random priorities/arrivals/cancels on a tight pool: every request
    that survives must emit exactly the stream an unconstrained engine
    produced, and neither the device allocator nor the host tier may
    leak a page."""
    cfg, model, params = served
    rng = np.random.default_rng(seed)
    n_req = 5
    shared = rng.integers(0, cfg.vocab_size, 8).tolist()
    proto = []
    for rid in range(n_req):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(2, 9))).tolist()
        prompt = (shared + tail) if rng.integers(0, 2) else tail
        sp = (SamplingParams(temperature=0.8, top_k=20, seed=5)
              if rng.integers(0, 2) else None)
        proto.append(dict(prompt=prompt, max_new=int(rng.integers(3, 7)),
                          priority=int(rng.integers(0, 3)),
                          arrival=float(rng.integers(0, 6)), sampling=sp))

    def reqs():
        return [Request(rid=i, prompt=list(p["prompt"]),
                        max_new=p["max_new"], priority=p["priority"],
                        sampling=p["sampling"])
                for i, p in enumerate(proto)]

    ref = PagedServeEngine(model, params, slots=n_req, max_len=64,
                           block_size=4, num_blocks=64, chunk=4,
                           kernel=kernel)
    ref_out = {r.rid: list(r.out) for r in ref.run(reqs())}

    eng = PagedServeEngine(model, params, slots=2, max_len=64, block_size=4,
                           num_blocks=12, chunk=4, kernel=kernel)
    handles = [eng.submit(r, arrival=p["arrival"])
               for r, p in zip(reqs(), proto)]
    cancelled: set[int] = set()
    steps = 0
    while eng.has_work():
        eng.step()
        steps += 1
        if steps % 4 == 0 and rng.integers(0, 2):
            victim = int(rng.integers(0, n_req))
            if handles[victim].cancel():
                cancelled.add(victim)
        eng.alloc.check()
        eng.host.check()
        assert steps < 2000, "interleaved run failed to converge"

    for rid in range(n_req):
        if rid in cancelled:
            continue
        assert handles[rid].req.out == ref_out[rid], (
            f"seed={seed} kernel={kernel} rid={rid} diverged")
    assert eng.host.in_use == eng.prefix.spilled   # swap records all drained
    eng.alloc.check()
    eng.host.check()
