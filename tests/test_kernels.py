"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode.

Each kernel sweeps shapes and dtypes and must assert_allclose against its
ref.py oracle — the repo-level native-vs-container comparison (the oracle
is the 'portable environment', the kernel the 'host-optimized' one)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hh_neuron import hh_step_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

RNG = np.random.default_rng(42)


# ------------------------------------------------------------------ HH


@pytest.mark.parametrize("n", [7, 128, 1000, 4096])
@pytest.mark.parametrize("dt", [0.0125, 0.025])
def test_hh_matches_oracle(n, dt):
    v0 = jnp.asarray(RNG.uniform(-90, 30, n), jnp.float32)
    m = jnp.asarray(RNG.uniform(0, 1, n), jnp.float32)
    h = jnp.asarray(RNG.uniform(0, 1, n), jnp.float32)
    nn = jnp.asarray(RNG.uniform(0, 1, n), jnp.float32)
    g = jnp.asarray(RNG.uniform(0, 8, n), jnp.float32)
    iax = jnp.asarray(RNG.uniform(-20, 20, n), jnp.float32)
    iext = jnp.asarray(RNG.uniform(0, 10, n), jnp.float32)
    out_k = hh_step_pallas(v0, m, h, nn, g, iax, iext, dt=dt, interpret=True)
    out_r = ref.hh_step_ref(v0, m, h, nn, g, iax, iext, dt=dt)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=3e-5)


def test_hh_block_shape_independence():
    n = 2048
    args = [jnp.asarray(RNG.uniform(0, 1, n), jnp.float32) for _ in range(7)]
    a = hh_step_pallas(*args, dt=0.025, block_rows=8, interpret=True)
    b = hh_step_pallas(*args, dt=0.025, block_rows=4, interpret=True)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


# --------------------------------------------------------------- flash


@pytest.mark.parametrize("s,bq,bk", [(128, 64, 64), (256, 128, 128),
                                     (256, 64, 128), (512, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(s, bq, bk, dtype, causal):
    bh, d = 3, 64
    q = jnp.asarray(RNG.standard_normal((bh, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((bh, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((bh, s, d)), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_block_skipping_is_exact():
    """Causal block skipping must not change results vs full iteration."""
    bh, s, d = 2, 256, 32
    q = jnp.asarray(RNG.standard_normal((bh, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, s, d)), jnp.float32)
    a = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_k=64,
                               interpret=True)
    b = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# ----------------------------------------------------------------- SSD


@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32), (256, 64)])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_matches_chunked_oracle(s, chunk, g):
    b, h, p, n = 2, 4, 32, 16
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.1, 1.0, h), jnp.float32)
    b_in = jnp.asarray(RNG.standard_normal((b, s, g, n)), jnp.float32)
    c_in = jnp.asarray(RNG.standard_normal((b, s, g, n)), jnp.float32)
    yk, fk = ssd_scan_pallas(x, dt, a, b_in, c_in, chunk, interpret=True)
    yr, fr = ref.ssd_scan_ref(x, dt, a, b_in, c_in, chunk)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(fk), np.asarray(fr),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_equals_sequential():
    """The chunked SSD oracle itself must equal the O(S) recurrence —
    validating the oracle against an independent formulation."""
    b, s, h, p, n = 1, 96, 2, 16, 8
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.2, (b, s, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.05, 0.8, h), jnp.float32)
    b_in = jnp.asarray(RNG.standard_normal((b, s, 1, n)), jnp.float32)
    c_in = jnp.asarray(RNG.standard_normal((b, s, 1, n)), jnp.float32)
    y1, f1 = ref.ssd_scan_ref(x, dt, a, b_in, c_in, 32)
    y2, f2 = ref.ssd_sequential_ref(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=2e-3, atol=2e-3)


def test_ssd_decode_step_matches_scan_tail():
    """Prefill-then-decode must continue the sequence exactly: run S+1
    tokens through the sequential reference vs S through the chunked scan
    + 1 decode step (the serving continuation invariant)."""
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    b, s, h, p, n = 1, 64, 2, 16, 8
    x = jnp.asarray(RNG.standard_normal((b, s + 1, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s + 1, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.05, 0.8, h), jnp.float32)
    b_in = jnp.asarray(RNG.standard_normal((b, s + 1, 1, n)), jnp.float32)
    c_in = jnp.asarray(RNG.standard_normal((b, s + 1, 1, n)), jnp.float32)

    y_full, _ = ref.ssd_sequential_ref(x, dt, a, b_in, c_in)
    _, state = ssd_chunked(x[:, :s], dt[:, :s], a, b_in[:, :s],
                           c_in[:, :s], 16)
    y_step, _ = ssd_decode_step(state, x[:, s], dt[:, s], a,
                                b_in[:, s], c_in[:, s])
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full[:, s]),
                               rtol=2e-3, atol=2e-3)
