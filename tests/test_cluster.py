"""Cluster serving: prefix-affinity routing over N paged replicas.

Covers the pure routing machinery (summaries, match depth, policies)
without a model, then the ClusterEngine against real workload traces:
token-exactness vs the single engine under every policy, affinity
accounting, load-aware spill, summary staleness, cancel of unrouted
requests, the aggregated report, and the audit layer's
``pathway-routing`` detection of a misrouting cluster.
"""
import jax
import numpy as np
import pytest

from repro.audit import (AuditContext, DEFAULT_REGISTRY, Evidence,
                         ExpectedSignature, Rule, Tracer)
from repro.serve import (AffinityPolicy, BloomSummary, ClusterEngine,
                         ExactSummary, PagedServeEngine, RandomPolicy,
                         Request, RoundRobinPolicy, SamplingParams,
                         chain_hashes, compare_engines, generate,
                         make_policy, match_depth, smoke_specs,
                         token_matrix)

GEOM = dict(slots=2, max_len=48, block_size=8, chunk=4)
MAX_NEW = 4


@pytest.fixture(scope="module")
def served():
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def chat_trace(served):
    cfg, _, _ = served
    return generate(smoke_specs(vocab_size=cfg.vocab_size, seed=0)[0])


def _requests(trace):
    reqs = trace.requests()
    for r in reqs:
        r.max_new = MAX_NEW
    return reqs


# ------------------------------------------------------------- summaries


def test_exact_summary_membership():
    s = ExactSummary()
    for h in (3, 99, 2**63):
        s.add(h)
    assert 3 in s and 99 in s and 2**63 in s
    assert 7 not in s
    assert len(s) == 3


def test_bloom_summary_no_false_negatives_and_low_fp():
    s = BloomSummary(bits=4096, k=3)
    rng = np.random.default_rng(0)
    member = [int(h) for h in rng.integers(0, 2**63, size=64)]
    for h in member:
        s.add(h)
    assert all(h in s for h in member)          # never a false negative
    probe = [int(h) for h in rng.integers(0, 2**63, size=2000)]
    fp = sum(1 for h in probe if h not in member and h in s)
    assert fp / len(probe) < 0.05               # ~64 keys in 4096 bits


def test_bloom_summary_validates_geometry():
    with pytest.raises(ValueError):
        BloomSummary(bits=0)
    with pytest.raises(ValueError):
        BloomSummary(k=9)


def test_match_depth_counts_leading_blocks_only():
    s = ExactSummary()
    tokens = list(range(32))
    hashes = chain_hashes(tokens, 8)
    for h in hashes[:2]:
        s.add(h)
    assert match_depth(s, hashes) == 2
    # a hole stops the walk even if deeper hashes are present
    s2 = ExactSummary()
    s2.add(hashes[0])
    s2.add(hashes[2])
    assert match_depth(s2, hashes) == 1
    assert match_depth(ExactSummary(), hashes) == 0


# -------------------------------------------------------------- policies


class _FakeReplica:
    def __init__(self, idx, load, slots=2):
        self.idx, self.load, self.slots = idx, load, slots


def test_make_policy_resolves_names_and_passthrough():
    assert isinstance(make_policy("affinity"), AffinityPolicy)
    assert isinstance(make_policy("round_robin"), RoundRobinPolicy)
    assert isinstance(make_policy("random", seed=3), RandomPolicy)
    pol = AffinityPolicy(spill_factor=3.0)
    assert make_policy(pol) is pol
    with pytest.raises(ValueError):
        make_policy("nearest")


def test_affinity_policy_prefers_deepest_match():
    pol = AffinityPolicy()
    reps = [_FakeReplica(0, 0), _FakeReplica(1, 0), _FakeReplica(2, 0)]
    idx, kind = pol.choose(None, [1, 3, 2], reps)
    assert (idx, kind) == (1, "affine")


def test_affinity_policy_cold_routes_to_least_loaded():
    pol = AffinityPolicy()
    reps = [_FakeReplica(0, 5), _FakeReplica(1, 1), _FakeReplica(2, 2)]
    idx, kind = pol.choose(None, [0, 0, 0], reps)
    assert (idx, kind) == (1, "cold")


def test_affinity_policy_spills_off_saturated_replica():
    pol = AffinityPolicy(spill_factor=2.0)
    # replica 0 holds the prefix but is saturated (load 4 >= 2.0 * 2)
    reps = [_FakeReplica(0, 4, slots=2), _FakeReplica(1, 0, slots=2)]
    idx, kind = pol.choose(None, [2, 0], reps)
    assert (idx, kind) == (1, "spill")
    # not saturated: affinity wins even against an idle sibling
    reps = [_FakeReplica(0, 3, slots=2), _FakeReplica(1, 0, slots=2)]
    idx, kind = pol.choose(None, [2, 0], reps)
    assert (idx, kind) == (0, "affine")


def test_round_robin_cycles():
    pol = RoundRobinPolicy()
    reps = [_FakeReplica(i, 0) for i in range(3)]
    picks = [pol.choose(None, [0, 0, 0], reps)[0] for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_random_policy_is_seed_deterministic():
    reps = [_FakeReplica(i, 0) for i in range(4)]
    a = [RandomPolicy(seed=5).choose(None, [0] * 4, reps)[0]
         for _ in range(1)]
    picks1 = [make_policy("random", seed=5).choose(None, [0] * 4, reps)[0]
              for _ in range(8)]
    pol = make_policy("random", seed=5)
    picks2 = [pol.choose(None, [0] * 4, reps)[0] for _ in range(8)]
    pol3 = make_policy("random", seed=5)
    picks3 = [pol3.choose(None, [0] * 4, reps)[0] for _ in range(8)]
    assert picks2 == picks3
    assert a[0] == picks2[0]
    assert len(set(picks2)) > 1                 # actually scatters


# ------------------------------------------------------ engine behaviour


def test_cluster_validates_construction(served):
    _, model, params = served
    with pytest.raises(ValueError):
        ClusterEngine(model, params, replicas=0, **GEOM)
    with pytest.raises(ValueError):
        ClusterEngine(model, params, replicas=2, summary="lossy", **GEOM)
    with pytest.raises(ValueError):
        ClusterEngine(model, params, replicas=2, refresh_every=0, **GEOM)
    with pytest.raises(ValueError):
        ClusterEngine(model, params, replicas=2, routing="nearest", **GEOM)
    with pytest.raises(ValueError):
        ClusterEngine(model, params, replicas=2,
                      replica_tracers=[Tracer()], **GEOM)


def test_cluster_rejects_unplaceable_request_at_submit(served):
    _, model, params = served
    eng = ClusterEngine(model, params, replicas=2, **GEOM)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=[1], max_new=200))


def test_cluster_token_exact_vs_single_engine_all_policies(
        served, chat_trace):
    """Counter-based sampling is placement-independent: every routing
    policy must reproduce the single paged engine's streams exactly."""
    _, model, params = served
    n = len(chat_trace.requests())
    single = PagedServeEngine(model, params, **GEOM)
    ref = token_matrix(single.run(_requests(chat_trace),
                                  arrivals=list(chat_trace.arrivals)),
                       n, MAX_NEW)
    for routing in ("affinity", "round_robin", "random"):
        eng = ClusterEngine(model, params, replicas=3, routing=routing,
                            **GEOM)
        got = token_matrix(eng.run(_requests(chat_trace),
                                   arrivals=list(chat_trace.arrivals)),
                           n, MAX_NEW)
        assert (got == ref).all(), routing


def test_compare_engines_cluster_mode_sampled(served, chat_trace):
    _, model, params = served
    sp = SamplingParams(temperature=0.8, top_k=16, seed=9)
    rep = compare_engines(model, params, lambda: _requests(chat_trace),
                          sampling=sp, cluster={"replicas": 3},
                          **{k: v for k, v in GEOM.items()})
    assert rep.ok, rep.verdicts


def test_affinity_beats_random_on_shared_prefix_trace(served, chat_trace):
    """The routing quality signal the audit layer gates on: affinity
    converts its opportunities; seeded random routing does not."""
    _, model, params = served

    def run(routing):
        eng = ClusterEngine(model, params, replicas=3, routing=routing,
                            routing_seed=11, **GEOM)
        eng.run(_requests(chat_trace), arrivals=list(chat_trace.arrivals))
        return eng.report()

    healthy, misrouted = run("affinity"), run("random")
    assert healthy["affine_opportunities"] > 0
    assert healthy["routed_affinity"] == 1.0
    assert misrouted["routed_affinity"] < healthy["routed_affinity"]
    assert misrouted["shared_hit_rate"] < healthy["shared_hit_rate"]


def test_route_events_and_summary_rebuilds(served, chat_trace):
    _, model, params = served
    tr = Tracer()
    eng = ClusterEngine(model, params, replicas=2, tracer=tr, **GEOM)
    eng.run(_requests(chat_trace), arrivals=list(chat_trace.arrivals))
    n = len(chat_trace.requests())
    routes = tr.events("route")
    assert len(routes) == n
    assert {e.data["replica"] for e in routes} <= {0, 1}
    assert all(e.data["decision"] in ("affine", "spill", "cold")
               for e in routes)
    # each chosen replica's own tracer carries its route decisions too
    per_replica = sum(t.count("route") for t in eng.replica_tracers)
    assert per_replica == n
    # summaries were rebuilt from the report feed as caches filled
    assert eng.report()["summary_rebuilds"] > 0


def test_bloom_summary_routing_matches_exact(served, chat_trace):
    """With this few chains the Bloom digest should make the same
    decisions as the exact set (false positives are rare)."""
    _, model, params = served

    def decisions(summary):
        tr = Tracer()
        eng = ClusterEngine(model, params, replicas=3, summary=summary,
                            tracer=tr, **GEOM)
        eng.run(_requests(chat_trace), arrivals=list(chat_trace.arrivals))
        return [(e.data["rid"], e.data["replica"]) for e in
                tr.events("route")]

    assert decisions("exact") == decisions("bloom")


def test_refresh_every_staleness_still_token_exact(served, chat_trace):
    """A stale summary view may misroute; it must never corrupt output."""
    _, model, params = served
    n = len(chat_trace.requests())
    single = PagedServeEngine(model, params, **GEOM)
    ref = token_matrix(single.run(_requests(chat_trace),
                                  arrivals=list(chat_trace.arrivals)),
                       n, MAX_NEW)
    eng = ClusterEngine(model, params, replicas=3, refresh_every=7, **GEOM)
    got = token_matrix(eng.run(_requests(chat_trace),
                               arrivals=list(chat_trace.arrivals)),
                       n, MAX_NEW)
    assert (got == ref).all()
    assert eng.report()["summary_rebuilds"] >= 1


def test_cancel_unrouted_request_before_arrival(served, chat_trace):
    _, model, params = served
    tr = Tracer()
    eng = ClusterEngine(model, params, replicas=2, tracer=tr, **GEOM)
    req = _requests(chat_trace)[0]
    h = eng.submit(req, arrival=100.0)      # far future: never routed
    assert eng.has_work()
    assert h.cancel() is True
    assert req.cancelled and not eng.has_work()
    assert h.cancel() is False              # idempotent
    assert eng.report()["cancelled"] == 1
    assert eng.report()["routed"] == 0
    ev = tr.last("cancel")
    assert ev.data["phase"] == "waiting" and ev.data["released_pages"] == 0


def test_cluster_report_aggregates_replicas(served, chat_trace):
    _, model, params = served
    eng = ClusterEngine(model, params, replicas=3, **GEOM)
    eng.run(_requests(chat_trace), arrivals=list(chat_trace.arrivals))
    rep = eng.report()
    n = len(chat_trace.requests())
    assert rep["engine"] == "cluster" and rep["replica_engine"] == "paged"
    assert rep["served"] == n and rep["routed"] == n
    per = rep["per_replica"]
    assert len(per) == 3
    for key in ("served", "tokens_out", "prefill_tokens", "cached_tokens",
                "decode_steps", "preemptions"):
        assert rep[key] == sum(p[key] for p in per), key
    assert rep["pages"] == sum(p["pages"] for p in per)
    assert rep["compiles"] == max(p["compiles"] for p in per) == 1
    total = rep["prefill_tokens"] + rep["cached_tokens"]
    assert rep["shared_hit_rate"] == pytest.approx(
        rep["cached_tokens"] / total, abs=1e-3)


# ------------------------------------------------------ audit integration


def test_default_registry_judges_cluster_as_paged(served, chat_trace):
    """The serve-dense-paged rule reads through the cluster to its
    replica engine: a healthy cluster passes, and the engine check does
    not misfire on ``engine="cluster"``."""
    cfg, model, params = served
    tr = Tracer()
    eng = ClusterEngine(model, params, replicas=2, tracer=tr, **GEOM)
    eng.run(_requests(chat_trace), arrivals=list(chat_trace.arrivals))
    ctx = AuditContext(workload="serve", family=cfg.family, arch=cfg.name,
                       shared_prefix=True)
    findings = DEFAULT_REGISTRY.evaluate(
        ctx, Evidence(tracer=tr, engine_report=eng.report()))
    assert findings == []


def test_pathway_routing_finding_fires_on_misrouting(served, chat_trace):
    cfg, model, params = served

    def report(routing):
        eng = ClusterEngine(model, params, replicas=3, routing=routing,
                            routing_seed=11, **GEOM)
        eng.run(_requests(chat_trace), arrivals=list(chat_trace.arrivals))
        return eng.report()

    healthy = report("affinity")
    rule = Rule(name="t-routing", workloads=("serve",),
                expect=ExpectedSignature(
                    min_routed_affinity=0.8 * healthy["routed_affinity"],
                    min_shared_hit_rate=0.85 * healthy["shared_hit_rate"]))
    ctx = AuditContext(workload="serve", family=cfg.family, arch=cfg.name,
                       shared_prefix=True)
    from repro.audit import ExpectationRegistry

    reg = ExpectationRegistry([rule])
    assert reg.evaluate(ctx, Evidence(engine_report=healthy)) == []
    kinds = [f["kind"] for f in
             reg.evaluate(ctx, Evidence(engine_report=report("random")))]
    assert kinds and set(kinds) == {"pathway-routing"}


def test_routed_affinity_vacuous_without_opportunities():
    """No affinity opportunity -> no routing finding (nothing to
    convert), even with a floor of 1.0."""
    rule = Rule(name="t", workloads=("serve",),
                expect=ExpectedSignature(min_routed_affinity=1.0))
    ctx = AuditContext(workload="serve", family="dense")
    from repro.audit import ExpectationRegistry

    rep = {"engine": "cluster", "routed_affinity": 0.0,
           "affine_opportunities": 0}
    assert ExpectationRegistry([rule]).evaluate(
        ctx, Evidence(engine_report=rep)) == []
