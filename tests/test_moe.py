"""MoE dispatch invariants (capacity, routing, combine) on the local path;
the sharded a2a path is covered by tests/test_integration.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # invariants still run via the conftest property loop
    from conftest import given, settings, st

from repro.configs import ALL_ARCHS, reduced
from repro.models.moe import _capacity, _moe_local, moe_specs
from repro.models import params as P


def _cfg(**kw):
    import dataclasses
    base = reduced(ALL_ARCHS["granite-moe-1b-a400m"])
    return dataclasses.replace(base, **kw)


def _run(cfg, x, key=0):
    p = P.initialize(moe_specs(cfg, None), jax.random.PRNGKey(key))
    return _moe_local(cfg, p, x, None, 1)


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    y, aux = _run(cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    assert aux.shape == (2, 16)
    # balanced-ish random routing: aux ~ 1.0 for uniform router
    assert 0.5 < float(aux[0, 0]) < 4.0


def test_moe_capacity_drops_tokens_but_not_correctness():
    """With capacity_factor tiny, outputs shrink toward zero (dropped
    tokens pass through residual as zeros) but never NaN."""
    cfg_small = _cfg(capacity_factor=0.05)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg_small.d_model),
                          jnp.bfloat16)
    y_small, _ = _run(cfg_small, x)
    cfg_big = _cfg(capacity_factor=8.0)
    y_big, _ = _run(cfg_big, x)
    assert bool(jnp.all(jnp.isfinite(y_small.astype(jnp.float32))))
    n_small = float(jnp.linalg.norm(y_small.astype(jnp.float32)))
    n_big = float(jnp.linalg.norm(y_big.astype(jnp.float32)))
    assert n_small < n_big


def test_moe_no_drop_when_capacity_exact():
    """Tiny token counts use exact capacity (decode path): zero drops, so
    doubling capacity further must not change the output."""
    cfg = _cfg()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 1, cfg.d_model),
                          jnp.bfloat16)
    y1, _ = _run(cfg, x)
    import dataclasses
    y2, _ = _run(dataclasses.replace(cfg, capacity_factor=cfg.capacity_factor * 2), x)
    # n*k small => cap = ceil(n*k*cf/E) >= 1 slot per expert either way;
    # verify the combine is stable across capacity settings
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=1e-2,
                               atol=1e-2)


@given(st.integers(4, 64), st.integers(2, 16), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_capacity_formula(n, e, k):
    import dataclasses
    cfg = dataclasses.replace(_cfg(), n_experts=e, top_k=min(k, e))
    cap = _capacity(n, cfg)
    assert cap >= 1
    assert cap <= max(int(n * cfg.top_k * cfg.capacity_factor / e), 1) + 1
