"""Workload-trace generator: determinism, shared-prefix family
structure, arrival-process shapes, and the Request-minting contract the
serving engines and ``compare_engines`` consume."""
import dataclasses

import pytest

from repro.serve.api import SamplingParams
from repro.serve.workloads import (ARRIVALS, FAMILIES, WorkloadSpec,
                                   generate, smoke_specs)


def spec(**kw):
    defaults = dict(name="t", family="chat", arrival="uniform",
                    n_requests=12, vocab_size=50, seed=3, max_new=4,
                    prefix_len=8, n_streams=3, suffix_lo=2, suffix_hi=5)
    defaults.update(kw)
    return WorkloadSpec(**defaults)


# ---------------------------------------------------------- determinism


def test_same_spec_generates_identical_trace():
    for family in FAMILIES:
        for arrival in ARRIVALS:
            s = spec(family=family, arrival=arrival)
            a, b = generate(s), generate(s)
            assert a.prompts == b.prompts, (family, arrival)
            assert a.arrivals == b.arrivals, (family, arrival)
            assert a.priorities == b.priorities, (family, arrival)


def test_seed_changes_prompts_and_arrivals():
    a = generate(spec(arrival="heavy-tail"))
    b = generate(spec(arrival="heavy-tail", seed=4))
    assert a.prompts != b.prompts
    assert a.arrivals != b.arrivals


def test_tokens_never_alias_the_pad_id():
    for family in FAMILIES:
        tr = generate(spec(family=family, vocab_size=5))
        assert all(1 <= t < 5 for p in tr.prompts for t in p), family


# -------------------------------------------------- family prefix shapes


def test_chat_family_shares_prefix_per_tenant():
    s = spec(family="chat", n_requests=9, n_streams=3)
    tr = generate(s)
    systems = [p[:s.prefix_len] for p in tr.prompts[:3]]
    assert len({tuple(x) for x in systems}) == 3      # tenants distinct
    for i, p in enumerate(tr.prompts):
        assert p[:s.prefix_len] == systems[i % 3]     # cycled per-tenant
        assert s.suffix_lo <= len(p) - s.prefix_len <= s.suffix_hi


def test_rag_family_shares_one_global_context():
    s = spec(family="rag", prefix_len=16)
    tr = generate(s)
    ctx = tr.prompts[0][:16]
    assert all(p[:16] == ctx for p in tr.prompts)
    assert tr.shared_prefix_stats()["reuse_frac"] > 0.5


def test_agent_family_grows_round_robin_by_turn():
    """Prompt order is (agent0 t0, agent1 t0, ..., agent0 t1, ...) and
    each turn's prompt extends that agent's previous turn exactly — so
    nondecreasing arrivals never request turn k before its turn k-1."""
    s = spec(family="agent", n_requests=12, n_streams=3, prefix_len=6)
    tr = generate(s)
    for i, p in enumerate(tr.prompts):
        turn, agent = divmod(i, 3)
        assert len(p) == 6 + turn * s.grow
        if turn:
            prev = tr.prompts[(turn - 1) * 3 + agent]
            assert p[:len(prev)] == prev


def test_agent_trace_truncates_to_n_requests():
    tr = generate(spec(family="agent", n_requests=7, n_streams=3, turns=4))
    assert tr.n_requests == 7


# ------------------------------------------------------------- arrivals


def test_arrival_ticks_are_nondecreasing_for_every_process():
    for arrival in ARRIVALS:
        tr = generate(spec(arrival=arrival, n_requests=40))
        assert all(b >= a for a, b in zip(tr.arrivals, tr.arrivals[1:])), (
            arrival)
        assert all(t >= 0 for t in tr.arrivals)


def test_uniform_arrivals_are_fixed_gaps():
    tr = generate(spec(arrival="uniform", mean_gap=3.0, n_requests=4))
    assert tr.arrivals == [3.0, 6.0, 9.0, 12.0]


def test_bursty_arrivals_cluster_between_quiet_gaps():
    s = spec(arrival="bursty", n_requests=12, burst_size=4, burst_gap=30.0)
    gaps = [b - a for a, b in zip([0.0] + generate(s).arrivals,
                                  generate(s).arrivals)]
    heads = [g for i, g in enumerate(gaps) if i % 4 == 0 and i]
    members = [g for i, g in enumerate(gaps) if i % 4 != 0 or not i]
    assert all(g == 30.0 for g in heads)      # quiet period between bursts
    assert all(g < 1.0 for g in members)      # near-simultaneous inside


# ---------------------------------------------------- spec + trace API


def test_spec_validation_rejects_bad_fields():
    with pytest.raises(ValueError, match="family"):
        spec(family="batch")
    with pytest.raises(ValueError, match="arrival"):
        spec(arrival="poissonish")
    with pytest.raises(ValueError, match="n_requests"):
        spec(n_requests=0)
    with pytest.raises(ValueError, match="vocab_size"):
        spec(vocab_size=1)
    with pytest.raises(ValueError, match="suffix_lo"):
        spec(suffix_lo=0)
    with pytest.raises(ValueError, match="turns"):
        spec(family="agent", turns=0)
    with pytest.raises(ValueError, match="priorities"):
        spec(priorities=())


def test_max_prompt_len_bounds_every_generated_prompt():
    for family in FAMILIES:
        s = spec(family=family)
        tr = generate(s)
        longest = max(len(p) for p in tr.prompts)
        assert longest <= s.max_prompt_len, family
        assert tr.max_feed == longest + s.max_new


def test_requests_are_fresh_per_call_with_rid_and_priority():
    s = spec(priorities=(0, 2))
    tr = generate(s)
    a, b = tr.requests(), tr.requests()
    assert [r.rid for r in a] == list(range(s.n_requests))
    assert [r.priority for r in a] == [0, 2] * (s.n_requests // 2)
    assert all(x is not y for x, y in zip(a, b))       # engines mutate
    assert all(x.prompt == y.prompt and x.prompt is not y.prompt
               for x, y in zip(a, b))
    assert all(r.sampling is None for r in a)          # greedy by default


def test_sampled_spec_carries_deterministic_sampling_params():
    s = spec(temperature=0.7, top_k=10, seed=9)
    [r, *_] = generate(s).requests()
    assert isinstance(r.sampling, SamplingParams)
    assert r.sampling.temperature == 0.7 and r.sampling.top_k == 10
    assert r.sampling.seed == 9
    assert spec(temperature=0.0).sampling is None


def test_spec_is_frozen_and_usable_as_a_cache_key():
    s = spec()
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.seed = 1
    assert hash(spec()) == hash(spec())


# ------------------------------------------------------ canonical suite


def test_smoke_specs_cover_families_and_fit_the_smoke_engine():
    specs = smoke_specs(vocab_size=50, seed=0)
    assert sorted(s.family for s in specs) == sorted(FAMILIES)
    assert len({s.name for s in specs}) == len(specs)
    assert len({s.arrival for s in specs}) == len(specs)
    for s in specs:
        tr = generate(s)
        assert tr.max_feed <= 64, s.name          # smoke engine max_len
        assert tr.shared_prefix_stats()["reuse_frac"] > 0.4, s.name
        d = tr.describe()
        assert d["workload"] == s.name and d["n_requests"] == s.n_requests
