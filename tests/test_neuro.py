"""Arbor/NEURON-analogue ring network: physiology, propagation dynamics,
BSP exchange semantics, kernel-path parity (the dual-environment check on
the paper's own workload)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.neuro.cable import CellConfig, init_state, step
from repro.neuro.ring import RingConfig, is_ring_head, source_of
from repro.neuro.sim import simulate


def test_resting_cell_stays_at_rest():
    cfg = CellConfig(n_compartments=4)
    st = init_state(8, cfg)
    for _ in range(200):
        st, spiked = step(st, cfg, jnp.zeros(8), jnp.zeros(8))
        assert not bool(jnp.any(spiked))
    assert float(jnp.max(jnp.abs(st.v - (-65.0)))) < 2.0


def test_stimulated_cell_spikes_once_then_repolarizes():
    cfg = CellConfig(n_compartments=4)
    st = init_state(1, cfg)
    spikes = 0
    for i in range(1200):  # 30 ms
        i_ext = jnp.full((1,), 20.0) if i < 200 else jnp.zeros(1)
        st, spiked = step(st, cfg, jnp.zeros(1), i_ext)
        spikes += int(spiked[0])
    assert spikes >= 1
    assert float(st.v[0, 0]) < 0.0  # back below threshold


def test_ring_wiring():
    cfg = RingConfig(n_cells=12, n_rings=3)
    src = np.asarray(source_of(cfg))
    # within-ring predecessor with wraparound
    assert src[0] == 3 and src[1] == 0 and src[4] == 7 and src[8] == 11
    heads = np.asarray(is_ring_head(cfg))
    assert list(np.nonzero(heads)[0]) == [0, 4, 8]


def test_wave_propagates_one_cell_per_epoch():
    cfg = RingConfig(n_cells=32, t_end_ms=40.0,
                     cell=CellConfig(n_compartments=4))
    r = simulate(cfg)
    # one spike per reached cell, wavefront advances monotonically
    front = np.asarray(r.wavefront)
    assert (np.diff(front) >= 0).all()
    assert r.total_spikes == int(front[-1]) + 1
    assert r.total_spikes >= cfg.n_epochs - 1


def test_multi_ring_independence():
    cfg = RingConfig(n_cells=32, n_rings=4, t_end_ms=25.0,
                     cell=CellConfig(n_compartments=4))
    r = simulate(cfg)
    counts = np.asarray(r.spike_counts).reshape(4, 8)
    # every ring's wave advances the same way (identical dynamics)
    for ring in range(1, 4):
        np.testing.assert_array_equal(counts[0], counts[ring])


def test_distributed_equals_single_device():
    """MPI_Allgather-analogue parity: the BSP exchange must not change any
    spike (subprocess provides the multi-device runtime)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, numpy as np
        from repro.neuro.ring import RingConfig
        from repro.neuro.cable import CellConfig
        from repro.neuro.sim import simulate
        cfg = RingConfig(n_cells=32, t_end_ms=30.0,
                         cell=CellConfig(n_compartments=4))
        ref = simulate(cfg)
        from repro.launch.mesh import mesh_of
        mesh = mesh_of((4,), ("cells",))
        dist = simulate(cfg, mesh=mesh)
        assert np.array_equal(np.asarray(ref.spike_counts),
                              np.asarray(dist.spike_counts))
        assert np.array_equal(np.asarray(ref.wavefront),
                              np.asarray(dist.wavefront))
        print("PARITY OK", dist.total_spikes)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PARITY OK" in out.stdout


def test_pallas_kernel_path_parity():
    """The paper's native-vs-container comparison on its own workload:
    jnp oracle path vs Pallas HH kernel path must agree spike-for-spike."""
    cfg = RingConfig(n_cells=16, t_end_ms=20.0,
                     cell=CellConfig(n_compartments=4))
    a = simulate(cfg, use_pallas=False)
    b = simulate(cfg, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a.spike_counts),
                                  np.asarray(b.spike_counts))
