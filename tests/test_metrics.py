"""Live metrics layer: registry semantics, the Tracer→ServeMetrics
binding, the structured event log, the pure HTTP routing contract, and
the end-to-end determinism bar — same seed + same trace ⇒ byte-identical
``/metrics`` exposition, no port bound."""
import json

import pytest

from repro.audit.metrics import (GAP_BUCKETS, EventLog, Gauge, Histogram,
                                 MetricsRegistry, MetricsServer,
                                 ServeMetrics, query_jsonl)
from repro.audit.trace import TraceEvent, Tracer


# ------------------------------------------------------------ primitives


def test_counter_is_monotonic():
    r = MetricsRegistry()
    c = r.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)


def test_histogram_buckets_and_nearest_rank_quantiles():
    h = Histogram("h", buckets=(1.0, 2.0, 4.0))
    assert h.quantile(0.5) is None                    # empty: no estimate
    for v in (0.5, 1.0, 3.0, 9.0):
        h.observe(v)
    # bisect_left: a value equal to an edge lands in that edge's bucket
    assert h.counts == [2, 0, 1, 1]                   # last is +Inf
    assert h.sum == 13.5 and h.count == 4
    assert h.quantile(0.5) == 1.0
    # tail observations clamp to the last finite edge, never invented
    assert h.quantile(1.0) == 4.0
    with pytest.raises(ValueError, match="quantile"):
        h.quantile(1.5)
    snap = h.snapshot()
    assert snap["buckets"] == {"1": 2, "2": 2, "4": 3}
    assert snap["inf"] == 4 and snap["p99"] == 4.0
    with pytest.raises(ValueError, match="increasing"):
        Histogram("bad", buckets=(2.0, 1.0))


def test_registry_is_idempotent_and_typed():
    r = MetricsRegistry()
    a = r.counter("x")
    assert r.counter("x") is a                        # same instance back
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("x")
    assert isinstance(r.get("x"), type(a))
    with pytest.raises(KeyError):
        r.get("missing")


def test_prometheus_render_is_sorted_and_deterministic():
    r = MetricsRegistry()
    r.gauge("z_gauge", "last").set(2)
    r.counter("a_total", "first").inc(3)
    r.histogram("m_hist", buckets=(1.0, 2.0)).observe(1.5)
    text = r.render_prometheus()
    assert text == r.render_prometheus()              # pure render
    assert text.index("a_total") < text.index("m_hist") < text.index("z_gauge")
    assert "# TYPE a_total counter\na_total 3\n" in text
    assert 'm_hist_bucket{le="2"} 1' in text
    assert 'm_hist_bucket{le="+Inf"} 1' in text
    snap = r.snapshot()
    assert snap["counters"] == {"a_total": 3.0}
    assert snap["gauges"] == {"z_gauge": 2.0}
    assert snap["histograms"]["m_hist"]["count"] == 1


# ------------------------------------------------------------- event log


def _ev(seq, kind, **data):
    return TraceEvent(seq=seq, t=float(seq), kind=kind, data=data)


def test_event_log_query_filters_and_limit():
    log = EventLog()
    log.append(_ev(0, "submit", rid=0, tick=0.0))
    log.append(_ev(1, "first-token", rid=0, tick=3.0))
    log.append(_ev(2, "submit", rid=1, tick=4.0))
    log.append(_ev(3, "finish", rid=0, tick=9.0))
    assert len(log) == 4
    assert [r["rid"] for r in log.query(kind="submit")] == [0, 1]
    assert [r["kind"] for r in log.query(rid=0)] == [
        "submit", "first-token", "finish"]
    assert [r["seq"] for r in log.query(tick_min=3.0, tick_max=4.0)] == [1, 2]
    assert [r["seq"] for r in log.query(limit=2)] == [2, 3]  # recent wins


def test_event_log_is_bounded():
    log = EventLog(capacity=3)
    for i in range(10):
        log.append(_ev(i, "tick"))
    assert [r["seq"] for r in log.query()] == [7, 8, 9]


def test_event_log_jsonl_roundtrip(tmp_path):
    log = EventLog()
    log.append(_ev(0, "submit", rid=0, tick=0.0))
    log.append(_ev(1, "finish", rid=0, tick=5.0))
    text = log.dumps()
    assert text == log.dumps(kind=None)               # no-filter == full
    assert [json.loads(l)["kind"] for l in text.splitlines()] == [
        "submit", "finish"]
    p = tmp_path / "events.jsonl"
    assert log.dump(p) == 2
    # a dumped log answers the same queries the live one does
    recs = query_jsonl(p.read_text().splitlines(), kind="finish")
    assert [r["tick"] for r in recs] == [5.0]
    assert query_jsonl(["", "  "], rid=1) == []


# --------------------------------------------- ServeMetrics event binding


def test_serve_metrics_maps_lifecycle_events():
    tr = Tracer(clock=lambda: 0.0)
    m = ServeMetrics()
    m.attach(tr)
    tr.emit("engine-init", engine="paged", pages=10)
    tr.emit("submit", rid=0, tick=0.0)
    tr.emit("admit", rid=0, cached_tokens=8, pages_in_use=5)
    tr.emit("step", lanes=2, prefill_tokens=4)
    tr.emit("first-token", rid=0, tick=3.0, ttft_ticks=3.0)
    tr.emit("finish", rid=0, tick=11.0, tokens_out=5, pages_in_use=0)
    tr.emit("preempt", rid=1, pages_in_use=2)
    tr.emit("cancel", rid=1, pages_in_use=0)
    tr.emit("compile", fn="decode_chunk")

    assert m.submitted.value == 1 and m.finished.value == 1
    assert m.cancelled.value == 1 and m.preemptions.value == 1
    assert m.recompiles.value == 1
    assert m.tokens_out.value == 5 and m.cached_tokens.value == 8
    assert m.prefill_tokens.value == 4
    assert m.prefix_hit_rate.value == pytest.approx(8 / 12)
    assert m.pages_total.value == 10 and m.active_lanes.value == 2
    assert m.steps.value == 1
    assert m.ttft.count == 1 and m.ttft.quantile(0.5) == 4.0
    # mean gap (11 - 3) / (5 - 1) = 2.0 ticks
    assert m.gap.count == 1 and m.gap.sum == 2.0
    # occupancy sampled at admit/finish/preempt/cancel: 0.5, 0, 0.2, 0
    assert m.occupancy.count == 4
    assert m.occupancy.sum == pytest.approx(0.7)
    # pending first-token state is cleared on finish/cancel
    assert m._first_tick == {}


def test_serve_metrics_observe_report_folds_exact_counters():
    m = ServeMetrics()
    tr = Tracer(clock=lambda: 0.0)
    m.attach(tr)
    tr.emit("admit", rid=0, cached_tokens=6)
    tr.emit("step", lanes=1, prefill_tokens=4)
    # the report's lifetime counter wins when larger; never decrements
    m.observe_report({"prefill_tokens": 10})
    assert m.prefill_tokens.value == 10
    assert m.prefix_hit_rate.value == pytest.approx(6 / 16)
    m.observe_report({"prefill_tokens": 7})
    assert m.prefill_tokens.value == 10


# --------------------------------------------------------- HTTP routing


def _server_with_log():
    m = ServeMetrics()
    log = EventLog()
    log.append(_ev(0, "submit", rid=0, tick=0.0))
    log.append(_ev(1, "finish", rid=0, tick=5.0))
    return MetricsServer(m.registry, log)


def test_handle_routes_metrics_and_events_without_a_port():
    srv = _server_with_log()
    status, ctype, body = srv.handle("/metrics")
    assert status == 200 and ctype.startswith("text/plain")
    assert b"# TYPE serve_requests_submitted_total counter" in body

    status, ctype, body = srv.handle("/metrics.json")
    assert status == 200 and ctype == "application/json"
    snap = json.loads(body)
    assert "serve_ttft_ticks" in snap["histograms"]
    assert srv.handle("/metrics?format=json")[2] == body
    assert srv.handle("/metrics/")[:2] == (200, "text/plain; version=0.0.4")

    status, _, body = srv.handle("/events?kind=finish&limit=5")
    assert status == 200
    assert [json.loads(l)["kind"] for l in body.splitlines()] == ["finish"]
    body = srv.handle("/events?rid=0&tick_min=1")[2]
    [rec] = [json.loads(l) for l in body.splitlines()]
    assert rec["kind"] == "finish"

    assert srv.handle("/healthz") == (200, "application/json",
                                      b'{"ok": true}\n')
    assert srv.handle("/events?rid=abc")[0] == 400       # bad filter value
    assert srv.handle("/nope")[0] == 404
    assert MetricsServer(MetricsRegistry()).handle("/events")[0] == 404


def test_server_binds_and_serves_over_http():
    from urllib.request import urlopen

    srv = _server_with_log()
    port = srv.serve(port=0)                    # ephemeral
    assert srv.port == port
    try:
        with urlopen(f"http://127.0.0.1:{port}/healthz", timeout=5) as r:
            assert json.load(r)["ok"] is True
        with urlopen(f"http://127.0.0.1:{port}/metrics", timeout=5) as r:
            assert r.read() == srv.handle("/metrics")[2]
    finally:
        srv.close()
    assert srv.port is None


# -------------------------------------------- scheduler preemption knob


def test_scheduler_preemption_disabled_plans_no_victims():
    from repro.serve.scheduler import Plan, Scheduler

    def loaded(preemption):
        sched = Scheduler(slots=1, clock=lambda: 10.0,
                          preemption=preemption)
        low = sched.submit(object(), priority=0, arrival=0.0)
        sched.mark_running(low, slot=0, held_pages=4)
        sched.submit(object(), priority=2, arrival=1.0)
        return sched.schedule(free_slots=0, free_pages=0,
                              cost_fn=lambda e: 2)

    plan = loaded(preemption=True)
    assert len(plan.preempt) == 1 and len(plan.admit) == 1
    plan = loaded(preemption=False)
    assert isinstance(plan, Plan)
    assert plan.preempt == [] and plan.admit == []    # burst queues behind


# --------------------------------------------------- end-to-end bit bar


@pytest.mark.slow
def test_metrics_exposition_is_byte_identical_for_same_seed_and_trace():
    """The acceptance bar: two independent engines fed the same generated
    trace render byte-identical ``/metrics`` (text and JSON), via the
    pure ``handle()`` contract — no port bound anywhere."""
    import jax

    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import PagedServeEngine
    from repro.serve.workloads import WorkloadSpec, generate

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    trace = generate(WorkloadSpec(
        name="bit-bar", family="chat", arrival="bursty", n_requests=6,
        vocab_size=cfg.vocab_size, seed=13, max_new=4, prefix_len=8,
        n_streams=2, suffix_lo=2, suffix_hi=4, burst_size=3,
        burst_gap=8.0, priorities=(0, 1)))

    def run_once():
        tracer = Tracer()
        metrics = ServeMetrics()
        metrics.attach(tracer)
        log = EventLog()
        tracer.subscribe(log.append)
        eng = PagedServeEngine(model, params, slots=2, max_len=48,
                               block_size=8, chunk=4, tracer=tracer)
        eng.run(trace.requests(), arrivals=trace.arrivals)
        metrics.observe_report(eng.report())
        srv = MetricsServer(metrics.registry, log)
        return (srv.handle("/metrics")[2], srv.handle("/metrics.json")[2],
                srv.handle("/events?kind=finish")[2])

    a, b = run_once(), run_once()
    assert a[0] == b[0]                        # Prometheus text, bytes
    assert a[1] == b[1]                        # JSON snapshot, bytes
    # the event streams agree on everything but the wall-clock stamp
    strip = lambda body: [
        {k: v for k, v in json.loads(l).items() if k != "t"}
        for l in body.splitlines()]
    assert strip(a[2]) == strip(b[2])
    assert len(strip(a[2])) == 6               # every request finished
