"""Engine-protocol conformance: one suite, every engine.

``ServeEngine`` (contiguous), ``PagedServeEngine``, and ``ClusterEngine``
all advertise the same ``serve.api.Engine`` contract; this suite runs
the identical submit/step/drain/cancel/report scenarios against each so
a new engine cannot drift from the protocol silently.  Paged engines
additionally prove cancel page-cleanliness: after a cancel + drain, the
only pages still referenced are the ones the prefix cache deliberately
retains.
"""
import jax
import numpy as np
import pytest

from repro.serve import (ClusterEngine, Engine, PagedServeEngine, Request,
                         ServeEngine)

GEOM = dict(slots=2, max_len=48, block_size=8, chunk=4)
ENGINES = ["contiguous", "paged", "cluster"]


@pytest.fixture(scope="module")
def served():
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(params=ENGINES)
def make_engine(request, served):
    _, model, params = served
    kind = request.param

    def factory():
        if kind == "contiguous":
            return ServeEngine(model, params, slots=GEOM["slots"],
                               max_len=GEOM["max_len"])
        if kind == "paged":
            return PagedServeEngine(model, params, **GEOM)
        return ClusterEngine(model, params, replicas=2, **GEOM)

    factory.kind = kind
    return factory


def _requests(cfg, n=4, shared=16, max_new=4, seed=3):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=shared).tolist()
    return [Request(rid=i,
                    prompt=prefix + rng.integers(
                        0, cfg.vocab_size, size=int(rng.integers(3, 9))
                    ).tolist(),
                    max_new=max_new)
            for i in range(n)]


def _paged_engines(eng):
    """The paged sub-engines of ``eng`` (itself, or its replicas)."""
    if isinstance(eng, ClusterEngine):
        return list(eng.replicas)
    return [eng] if isinstance(eng, PagedServeEngine) else []


def _assert_pages_clean(eng):
    for sub in _paged_engines(eng):
        # every non-cached page returned: only the prefix cache's
        # deliberately-retained chain blocks may still hold a reference
        assert sub.alloc.in_use == len(sub.prefix), (
            sub.alloc.in_use, len(sub.prefix))
        sub.alloc.check()


def test_satisfies_engine_protocol(make_engine):
    eng = make_engine()
    assert isinstance(eng, Engine)
    for name in ("submit", "step", "drain", "cancel", "has_work", "report"):
        assert callable(getattr(eng, name)), name


def test_submit_step_drain_roundtrip(served, make_engine):
    cfg, _, _ = served
    eng = make_engine()
    reqs = _requests(cfg)
    handles = [eng.submit(r) for r in reqs]
    assert eng.has_work()
    assert all(h.rid == r.rid for h, r in zip(handles, reqs))
    assert not any(h.done for h in handles)     # submit starts no work
    first = eng.step()
    assert isinstance(first, list)
    done = first + eng.drain()
    assert not eng.has_work()
    assert sorted(r.rid for r in done) == [r.rid for r in reqs]
    assert all(h.done for h in handles)
    for h, r in zip(handles, reqs):
        assert h.result() is r
        assert list(h.tokens()) == list(r.out)
        assert len(r.out) == r.max_new
    _assert_pages_clean(eng)


def test_report_carries_protocol_counters(served, make_engine):
    cfg, _, _ = served
    eng = make_engine()
    eng.submit(_requests(cfg, n=2)[0])
    eng.drain()
    rep = eng.report()
    for key in ("engine", "served", "cancelled", "decode_steps",
                "tokens_out", "mean_batch_occupancy", "compiles"):
        assert key in rep, key
    assert rep["served"] == 1 and rep["cancelled"] == 0
    assert rep["tokens_out"] >= 1 and rep["decode_steps"] >= 1


def test_cancel_waiting_request(served, make_engine):
    cfg, _, _ = served
    eng = make_engine()
    reqs = _requests(cfg)
    handles = [eng.submit(r) for r in reqs]
    victim = handles[-1]                       # queued behind the batch
    assert victim.cancel() is True
    assert victim.cancelled and not victim.finished
    assert victim.cancel() is False            # idempotent
    done = eng.drain()
    assert victim.rid not in {r.rid for r in done}
    assert len(done) == len(reqs) - 1
    rep = eng.report()
    assert rep["cancelled"] == 1 and rep["served"] == len(reqs) - 1
    _assert_pages_clean(eng)


def test_cancel_active_request_releases_pages(served, make_engine):
    cfg, _, _ = served
    eng = make_engine()
    reqs = _requests(cfg, n=2, max_new=8)
    handles = [eng.submit(r) for r in reqs]
    eng.step()                                  # both running
    assert handles[0].cancel() is True
    done = eng.drain()
    assert {r.rid for r in done} == {reqs[1].rid}
    assert eng.report()["cancelled"] == 1
    assert not eng.has_work()
    _assert_pages_clean(eng)


def test_cancel_finished_request_is_refused(served, make_engine):
    cfg, _, _ = served
    eng = make_engine()
    h = eng.submit(_requests(cfg, n=1)[0])
    eng.drain()
    assert h.done
    assert h.cancel() is False


def test_future_arrivals_hold_until_due(served, make_engine):
    cfg, _, _ = served
    eng = make_engine()
    now, later = _requests(cfg, n=2)
    eng.submit(now, arrival=0.0)
    eng.submit(later, arrival=6.0)
    eng.step()
    assert not later.t_first                    # not admitted yet
    done = eng.drain()
    assert {r.rid for r in done} == {now.rid, later.rid}
    assert eng.report()["served"] == 2
