"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step + prefill/decode on CPU; output shapes + finiteness.
(Full configs are exercised only via the dry-run, per the assignment.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, applicable_shapes, reduced
from repro.configs.base import RunConfig, ShapeConfig, TrainConfig
from repro.models import build
from repro.models.stack import param_count
from repro.train.step import init_train_state, make_train_step

ARCHS = sorted(ALL_ARCHS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(ALL_ARCHS[name])
            model = build(cfg)
            params = model.init_params(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(built, name):
    cfg, model, params = built(name)
    shape = ShapeConfig("s", "train", 32, 2)
    batch = model.sample_batch(shape, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert jnp.isfinite(loss), (name, loss)
    assert float(loss) > 0


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_runs_and_updates(built, name):
    cfg, model, params = built(name)
    shape = ShapeConfig("s", "train", 32, 2)
    run = RunConfig(model=cfg, shape=shape,
                    train=TrainConfig(remat="full", learning_rate=1e-3))
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, run))
    batch = model.sample_batch(shape, jax.random.PRNGKey(1))
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_state.opt.step) == 1
    # at least one parameter must actually change
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(new_state.params)))
    assert changed, name


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_consistency(built, name):
    """Greedy decode after prefill must equal teacher-forced forward:
    the cached path and the full path are the paper's two 'environments'."""
    cfg, model, params = built(name)
    s = 16
    batch = model.sample_batch(ShapeConfig("p", "prefill", s, 2),
                               jax.random.PRNGKey(2))
    logits_pre, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=s + 4))(params, batch)

    # teacher-forced logits at the last position must match prefill's
    fwd_batch = dict(batch)
    fwd_batch["labels"] = batch["tokens"]
    from repro.models import stack
    full_logits, _ = jax.jit(
        lambda p, b: stack.forward(cfg, p, b))(params, fwd_batch)
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=3e-2, atol=3e-2)

    # one decode step advances without NaNs and returns the right shapes
    tok = jnp.argmax(logits_pre, axis=-1)[:, None].astype(jnp.int32)
    pos = jnp.full((2,), s, jnp.int32)
    logits_dec, cache2 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert logits_dec.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits_dec)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_param_specs(name):
    """Full (unreduced) configs must declare specs with positive sizes and
    the published parameter counts (±15%)."""
    published = {
        "llama-3.2-vision-11b": 10.6e9, "mamba2-2.7b": 2.7e9,
        "phi3-mini-3.8b": 3.8e9, "phi3-medium-14b": 14e9,
        "deepseek-7b": 7e9, "deepseek-coder-33b": 33e9,
        "qwen3-moe-30b-a3b": 30.5e9, "granite-moe-1b-a400m": 1.3e9,
        "whisper-medium": 0.77e9, "zamba2-2.7b": 2.7e9,
    }
    n = param_count(ALL_ARCHS[name])
    expect = published[name]
    assert 0.65 * expect < n < 1.35 * expect, (name, n, expect)


def test_applicable_shapes_policy():
    for name in ARCHS:
        shapes = applicable_shapes(name)
        assert "train_4k" in shapes and "decode_32k" in shapes
        if name in ("mamba2-2.7b", "zamba2-2.7b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
