"""Property-style tests for the paged-KV layer: allocator invariants under
random traces, prefix-cache hit/miss accounting, and pool round-trips."""
import numpy as np
import pytest

from repro.serve.paging import (BlockAllocator, BlockAllocatorError, KVPool,
                                PrefixCache, chain_hashes, pages_for)


# ------------------------------------------------------------- allocator


def test_alloc_free_roundtrip_under_random_traces():
    """Random request traces: every page allocated is eventually freed,
    the free list never leaks or duplicates, refcounts stay balanced."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        alloc = BlockAllocator(num_blocks=int(rng.integers(4, 32)),
                               block_size=int(rng.integers(1, 9)))
        held: list[int] = []   # one entry per reference we hold
        for _ in range(300):
            op = rng.random()
            if op < 0.45 and alloc.num_free:
                held.append(alloc.alloc())
            elif op < 0.65 and held:
                bid = held[int(rng.integers(0, len(held)))]
                alloc.incref(bid)
                held.append(bid)
            elif held:
                alloc.decref(held.pop(int(rng.integers(0, len(held)))))
            alloc.check()
            assert alloc.in_use == len(set(held))
        for bid in held:
            alloc.decref(bid)
        alloc.check()
        assert alloc.in_use == 0
        assert alloc.num_free == alloc.num_blocks
        assert alloc.stats.allocs == alloc.stats.frees


def test_no_double_free():
    alloc = BlockAllocator(4, 8)
    bid = alloc.alloc()
    alloc.decref(bid)
    with pytest.raises(BlockAllocatorError):
        alloc.decref(bid)


def test_free_unknown_block_raises():
    alloc = BlockAllocator(4, 8)
    with pytest.raises(BlockAllocatorError):
        alloc.decref(3)


def test_incref_unallocated_raises():
    alloc = BlockAllocator(4, 8)
    with pytest.raises(BlockAllocatorError):
        alloc.incref(0)


def test_oom_raises_and_counts():
    alloc = BlockAllocator(2, 8)
    alloc.alloc(), alloc.alloc()
    with pytest.raises(BlockAllocatorError):
        alloc.alloc()
    assert alloc.stats.oom_events == 1


def test_refcounted_sharing_frees_only_at_zero():
    alloc = BlockAllocator(2, 8)
    bid = alloc.alloc()
    alloc.incref(bid)        # second reader
    alloc.decref(bid)
    assert alloc.refcount(bid) == 1 and alloc.in_use == 1
    alloc.decref(bid)
    assert alloc.in_use == 0


# ------------------------------------------------------------ hash chain


def test_chain_hashes_prefix_property():
    """Chains agree exactly up to the first differing block and only full
    blocks participate."""
    rng = np.random.default_rng(1)
    for _ in range(20):
        bs = int(rng.integers(2, 8))
        a = rng.integers(0, 100, size=int(rng.integers(8, 40))).tolist()
        b = list(a)
        flip = int(rng.integers(0, len(b)))
        b[flip] = (b[flip] + 1) % 100
        ha, hb = chain_hashes(a, bs), chain_hashes(b, bs)
        assert ha == chain_hashes(list(a), bs)          # deterministic
        assert len(ha) == len(a) // bs                  # partial tail excluded
        agree = flip // bs                              # blocks before flip
        assert ha[:agree] == hb[:agree]
        assert ha[agree:] != hb[agree:] or agree == len(ha)


# ----------------------------------------------------------- prefix cache


def _register(cache: PrefixCache, tokens: list[int]) -> list[int]:
    """Register every full block of ``tokens``; returns the pages (the
    caller's references are released, the cache keeps its own)."""
    bids = []
    for h in chain_hashes(tokens, cache.allocator.block_size):
        bid = cache.allocator.alloc()
        assert cache.insert(h, bid)
        cache.allocator.decref(bid)   # writer's reference released
        bids.append(bid)
    return bids


def test_prefix_cache_hit_miss_accounting():
    alloc = BlockAllocator(16, 4)
    cache = PrefixCache(alloc)
    shared = list(range(8))           # two full blocks
    _register(cache, shared)
    assert cache.stats.insertions == 2

    # full hit on the shared prefix, miss on the divergent tail
    n, bids = cache.match(shared + [91, 92, 93, 94, 95])
    assert n == 8 and len(bids) == 2
    assert cache.stats.hit_blocks == 2 and cache.stats.miss_blocks == 1
    assert all(alloc.refcount(b) == 2 for b in bids)    # cache + caller

    # cold lookup: pure miss
    n2, bids2 = cache.match([50, 51, 52, 53])
    assert n2 == 0 and not bids2
    assert cache.stats.miss_blocks == 2
    assert 0 < cache.stats.hit_rate < 1

    for b in bids:
        alloc.decref(b)
    alloc.check()


def test_prefix_cache_match_cap_keeps_a_token_to_feed():
    """max_tokens caps the match so the engine always has >= 1 token whose
    logits seed decoding."""
    alloc = BlockAllocator(16, 4)
    cache = PrefixCache(alloc)
    prompt = list(range(8))
    _register(cache, prompt)
    n, bids = cache.match(prompt, max_tokens=len(prompt) - 1)
    assert n == 4 and len(bids) == 1   # second block would cover the tail
    for b in bids:
        alloc.decref(b)


def test_peek_takes_no_references():
    alloc = BlockAllocator(16, 4)
    cache = PrefixCache(alloc)
    prompt = list(range(8))
    bids = _register(cache, prompt)
    assert cache.peek(prompt + [99]) == 8
    assert all(alloc.refcount(b) == 1 for b in bids)


def test_eviction_respects_references_and_lru():
    alloc = BlockAllocator(8, 4)
    cache = PrefixCache(alloc)
    old = _register(cache, [1, 2, 3, 4])
    new = _register(cache, [5, 6, 7, 8])
    n, held = cache.match([5, 6, 7, 8, 0])    # touch + hold the newer entry
    assert n == 4
    assert cache.evictable() == 1             # only the old, unreferenced one
    assert cache.evict(5) == 1                # reclaims LRU (old), not held
    assert alloc.refcount(old[0]) == 0
    assert alloc.refcount(new[0]) == 2
    for b in held:
        alloc.decref(b)
    assert cache.evict(5) == 1                # now reclaimable
    alloc.check()
    assert alloc.in_use == 0


def test_lru_eviction_under_refcount_pressure():
    """Eviction strictly respects both axes at once: entries with reader
    references are never reclaimed no matter the pressure, and among the
    unreferenced ones the reclaim order is LRU — insertion order adjusted
    by ``match`` touches."""
    alloc = BlockAllocator(32, 4)
    cache = PrefixCache(alloc)
    entries = [list(range(4 * i, 4 * i + 4)) for i in range(6)]
    pages = [_register(cache, e)[0] for e in entries]

    # readers hold entries 1 and 3 (refcount pressure)
    held: list[int] = []
    for i in (1, 3):
        _, bids = cache.match(entries[i])
        held += bids
    # touch entry 0 so LRU order among unreferenced becomes 2, 4, 5, 0
    _, touch = cache.match(entries[0])
    for b in touch:
        alloc.decref(b)

    assert cache.evictable() == 4
    # demand more than is reclaimable: only the 4 unreferenced ones go
    assert cache.evict(100) == 4
    for i in (2, 4, 5, 0):
        assert alloc.refcount(pages[i]) == 0, i
        assert cache.peek(entries[i]) == 0            # gone from the map
    for i in (1, 3):
        assert alloc.refcount(pages[i]) == 2, i       # cache + reader
        assert cache.peek(entries[i]) == 4            # still served

    # pressure released: the survivors become reclaimable, LRU first
    for b in held:
        alloc.decref(b)
    assert cache.evict(1) == 1
    assert alloc.refcount(pages[1]) == 0              # older of the two
    assert alloc.refcount(pages[3]) == 1
    assert cache.evict(10) == 1
    alloc.check()
    assert alloc.in_use == 0 and len(cache) == 0


def test_partial_eviction_takes_lru_prefix_of_unreferenced():
    """Asking for fewer pages than are evictable reclaims exactly the
    LRU-first prefix, skipping referenced entries in between."""
    alloc = BlockAllocator(32, 4)
    cache = PrefixCache(alloc)
    entries = [list(range(4 * i, 4 * i + 4)) for i in range(4)]
    pages = [_register(cache, e)[0] for e in entries]
    _, held = cache.match(entries[0])                 # pin the oldest
    assert cache.evict(2) == 2                        # skips 0, takes 1, 2
    assert alloc.refcount(pages[0]) == 2
    assert [alloc.refcount(pages[i]) for i in (1, 2, 3)] == [0, 0, 1]
    for b in held:
        alloc.decref(b)


def test_insert_first_writer_wins():
    alloc = BlockAllocator(8, 4)
    cache = PrefixCache(alloc)
    [h] = chain_hashes([1, 2, 3, 4], 4)
    a, b = alloc.alloc(), alloc.alloc()
    assert cache.insert(h, a)
    assert not cache.insert(h, b)             # loser keeps its private page
    assert alloc.refcount(a) == 2 and alloc.refcount(b) == 1


# ------------------------------------------------------------------ pool


def test_kv_pool_roundtrip():
    rng = np.random.default_rng(2)
    pool = KVPool(num_blocks=4, block_size=3, layers=2, n_kv=2, head_dim=4,
                  dtype=np.float32)
    k = rng.standard_normal((2, 3, 2, 4)).astype(np.float32)
    v = rng.standard_normal((2, 3, 2, 4)).astype(np.float32)
    pool.write(2, k, v)
    k2, v2 = pool.read([2])
    np.testing.assert_array_equal(k2, k)
    np.testing.assert_array_equal(v2, v)
    kk, _ = pool.read([2, 2])
    assert kk.shape == (2, 6, 2, 4)


def test_pages_for():
    assert pages_for(0, 8) == 0
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
