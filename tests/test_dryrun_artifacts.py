"""Integrity of the committed dry-run artifacts: the multi-pod deliverable
is 'every (arch × applicable shape × mesh) cell compiles' — these tests
make the evidence itself CI-checkable (no re-compilation; they validate
the records produced by `python -m repro.launch.dryrun --both-meshes`)."""
import json
from pathlib import Path

import pytest

from repro.core.registry import all_cells

DRYRUN = Path("EXPERIMENTS/dryrun")

pytestmark = pytest.mark.skipif(
    not DRYRUN.exists(), reason="run `python -m repro.launch.dryrun "
                                "--both-meshes` first")


def _records():
    return [json.loads(f.read_text()) for f in sorted(DRYRUN.glob("*.json"))]


def test_every_cell_present_on_both_meshes():
    recs = _records()
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
    for arch, shape in all_cells():
        assert (arch, shape, "16x16") in seen, (arch, shape, "single-pod")
        assert (arch, shape, "2x16x16") in seen, (arch, shape, "multi-pod")


def test_every_cell_green_and_fits():
    for r in _records():
        cell = (r["arch"], r["shape"], r["mesh"])
        assert r["status"] == "ok", (cell, r.get("error"))
        mem = r["memory"]["per_device_total"]
        assert mem <= 16 * 2**30, (cell, f"{mem/2**30:.2f} GiB")


def test_cost_records_are_sane():
    for r in _records():
        cell = (r["arch"], r["shape"], r["mesh"])
        hc = r["hlo_cost"]
        assert hc["dot_flops"] > 0, cell
        assert hc["bytes"] > 0, cell
        # multi-pod must communicate at least across the pod axis
        if r["mesh"] == "2x16x16" and r["shape"] == "train_4k":
            assert r["collectives"]["total_moved_bytes"] > 0, cell
        # train cells: trip-weighted flops must exceed XLA's unweighted count
        if r["shape"] == "train_4k":
            assert hc["dot_flops"] > r["cost"].get("flops", 0) * 0.9, cell


def test_decode_cells_lower_serve_step():
    """decode shapes must have tiny compute (one token) and a cache-sized
    argument footprint — evidence they lowered decode_step, not train."""
    for r in _records():
        if r["shape"] not in ("decode_32k", "long_500k"):
            continue
        assert r["hlo_cost"]["dot_flops"] < 1e12, (r["arch"], r["shape"])
