"""Unified request-lifecycle serving API: the Engine protocol over both
engines, streaming handles, cancellation releasing pages and prefix-cache
references, and counter-based sampled decoding that is deterministic and
replayable across engines, runs, and preemption."""
import jax
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, reduced
from repro.models import build
from repro.serve import (Engine, PagedServeEngine, Request, RequestHandle,
                         SamplingParams, ServeEngine, compare_engines,
                         run_requests, token_matrix)

SAMPLED = SamplingParams(temperature=0.9, top_k=24, top_p=0.95, seed=17)


@pytest.fixture(scope="module")
def served():
    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n=4, shared=18, seed=7):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, size=shared).tolist()
    return [pre + rng.integers(0, cfg.vocab_size, size=4 + i).tolist()
            for i in range(n)]


def _paged(model, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_size", 8)
    kw.setdefault("chunk", 4)
    return PagedServeEngine(model, params, **kw)


# ------------------------------------------------------------- protocol


def test_both_engines_satisfy_the_protocol(served):
    cfg, model, params = served
    paged = _paged(model, params)
    contig = ServeEngine(model, params, slots=2, max_len=64)
    assert isinstance(paged, Engine) and isinstance(contig, Engine)
    for eng in (paged, contig):
        for method in ("submit", "step", "drain", "cancel", "has_work",
                       "report"):
            assert callable(getattr(eng, method))


def test_run_shim_has_one_shape_on_both_engines(served):
    """The retired run(list) call shape survives as one uniform shim:
    requests + optional arrivals, identical on both engines."""
    cfg, model, params = served
    prompts = _prompts(cfg, n=3)

    def reqs():
        return [Request(rid=i, prompt=list(p), max_new=4)
                for i, p in enumerate(prompts)]

    a = ServeEngine(model, params, slots=2, max_len=64).run(
        reqs(), arrivals=[0.0, 0.0, 1.0])
    b = _paged(model, params).run(reqs(), arrivals=[0.0, 0.0, 1.0])
    assert (token_matrix(a, 3, 4) == token_matrix(b, 3, 4)).all()
    # and the function-shaped shim drives any Engine
    c = run_requests(_paged(model, params), reqs())
    assert len(c) == 3


def test_out_of_order_arrivals_agree_across_engines(served):
    """A future-dated request submitted first must not block a ready one
    behind it — on either engine (one protocol, one arrival semantics)."""
    cfg, model, params = served
    prompts = _prompts(cfg, n=2)

    def reqs():
        return [Request(rid=0, prompt=list(prompts[0]), max_new=3),
                Request(rid=1, prompt=list(prompts[1]), max_new=3)]

    for eng in (ServeEngine(model, params, slots=1, max_len=64),
                _paged(model, params, slots=1)):
        done = eng.run(reqs(), arrivals=[50.0, 0.0])
        assert [r.rid for r in done] == [1, 0]   # ready rid 1 served first


def test_handle_streams_tokens_incrementally(served):
    cfg, model, params = served
    eng = _paged(model, params)
    h = eng.submit(Request(rid=0, prompt=_prompts(cfg)[0], max_new=6))
    assert isinstance(h, RequestHandle) and not h.done
    seen = []
    for tok in h:
        seen.append(tok)
        # tokens arrive no later than the engine produces them
        assert len(seen) <= len(h.req.out)
    assert h.finished and seen == h.req.out and len(seen) == 6


def test_result_drains_to_completion_and_matches_run(served):
    cfg, model, params = served
    prompts = _prompts(cfg, n=2)
    eng = _paged(model, params)
    h0 = eng.submit(Request(rid=0, prompt=list(prompts[0]), max_new=5))
    h1 = eng.submit(Request(rid=1, prompt=list(prompts[1]), max_new=5))
    out0 = list(h0.result().out)
    assert h0.finished and len(out0) == 5
    ref = _paged(model, params).run(
        [Request(rid=0, prompt=list(prompts[0]), max_new=5),
         Request(rid=1, prompt=list(prompts[1]), max_new=5)])
    assert out0 == token_matrix(ref, 2, 5)[0].tolist()
    h1.result()
    assert h1.finished


# ---------------------------------------------------------- cancellation


def _assert_pages_clean(eng):
    """All non-free pages must be exactly the prefix cache's registered
    blocks (readers all released); the allocator invariants must hold.
    Kernel mode additionally requires every inactive slot's device
    page-table row to be cleared — a stale row would let the next
    occupant attend a freed (possibly reallocated) page."""
    eng.alloc.check()
    assert eng.alloc.in_use == len(eng.prefix)
    for bid in eng.prefix._map.values():
        assert eng.alloc.refcount(bid) == 1     # cache's own ref only
    if eng.view is not None:
        for slot in range(eng.slots):
            if slot not in eng.active:
                assert (eng.view.page_table[slot] == 0).all()


def test_cancel_mid_prefill_releases_pages(served):
    cfg, model, params = served
    rng = np.random.default_rng(0)
    long_prompt = rng.integers(0, cfg.vocab_size, size=40).tolist()
    eng = _paged(model, params, max_len=64, chunk=4)
    h = eng.submit(Request(rid=0, prompt=long_prompt, max_new=8))
    eng.step()
    eng.step()
    st = eng.active[h.entry.slot]
    assert st.pending, "request must still be mid-prefill for this test"
    held = len(st.shared) + len(st.private)
    assert held > 0
    assert h.cancel()
    assert h.cancelled and not h.finished and h.done
    assert h.entry.state == "cancelled"
    _assert_pages_clean(eng)
    assert not eng.has_work()
    # cancel is idempotent
    assert not h.cancel()


def test_cancel_mid_decode_releases_pages_and_stops_stream(served):
    cfg, model, params = served
    prompts = _prompts(cfg, n=2)
    eng = _paged(model, params)
    h0 = eng.submit(Request(rid=0, prompt=list(prompts[0]), max_new=16))
    h1 = eng.submit(Request(rid=1, prompt=list(prompts[1]), max_new=4))
    while not h0.req.out:
        eng.step()
    n_at_cancel = len(h0.req.out)
    assert h0.cancel()
    done = eng.drain()
    assert [r.rid for r in done] == [1]          # cancelled never completes
    assert len(h0.req.out) == n_at_cancel        # stream stopped immediately
    assert h1.finished and len(h1.req.out) == 4
    _assert_pages_clean(eng)


def test_cancel_waiting_request_never_occupies_a_slot(served):
    cfg, model, params = served
    prompts = _prompts(cfg, n=3)
    eng = _paged(model, params, slots=1)
    h0 = eng.submit(Request(rid=0, prompt=list(prompts[0]), max_new=4))
    h_wait = eng.submit(Request(rid=1, prompt=list(prompts[1]), max_new=4))
    eng.step()
    assert h_wait.entry.state == "waiting"
    assert h_wait.cancel()
    eng.drain()
    assert h0.finished and not h_wait.req.out
    assert eng.stats.cancelled == 1
    assert eng.sched.stats.cancellations == 1
    _assert_pages_clean(eng)


def test_cancelled_page_release_unblocks_waiting_work(served):
    """Cancellation must actually return capacity: a waiting request that
    only fits once the cancelled one's pages free must then be admitted."""
    cfg, model, params = served
    rng = np.random.default_rng(1)
    big = rng.integers(0, cfg.vocab_size, size=24).tolist()
    eng = _paged(model, params, slots=2, max_len=48, block_size=4,
                 num_blocks=10, chunk=4)
    h_big = eng.submit(Request(rid=0, prompt=big, max_new=8))
    h_blocked = eng.submit(Request(rid=1, prompt=list(big), max_new=8))
    eng.step()
    assert h_blocked.entry.state == "waiting"    # pool too small for both
    h_big.cancel()
    done = eng.drain()
    assert [r.rid for r in done] == [1]
    _assert_pages_clean(eng)


def test_contiguous_engine_cancels_waiting_and_active(served):
    cfg, model, params = served
    prompts = _prompts(cfg, n=3)
    eng = ServeEngine(model, params, slots=1, max_len=64)
    h0 = eng.submit(Request(rid=0, prompt=list(prompts[0]), max_new=12))
    h1 = eng.submit(Request(rid=1, prompt=list(prompts[1]), max_new=4))
    eng.step()                                   # h0 active, h1 waiting
    assert h0.cancel() and h1.cancel()
    assert not eng.has_work()
    assert eng.stats.cancelled == 2
    h2 = eng.submit(Request(rid=2, prompt=list(prompts[2]), max_new=4))
    assert len(h2.result().out) == 4             # engine still serves


# ------------------------------------------------- kernel-pinned oracles


def test_engine_kwargs_pins_kernel_on_and_off(served):
    """The oracle must be holdable over an explicitly chosen KV pathway:
    kernel-on (attend through the device page table) and kernel-off
    (dense working-cache gather) both reproduce the contiguous streams,
    greedy and sampled — no reliance on engine defaults or globals."""
    cfg, model, params = served
    prompts = _prompts(cfg)

    def make():
        return [Request(rid=i, prompt=list(p), max_new=8)
                for i, p in enumerate(prompts)]

    for kernel in ("paged", "gather"):
        for sampling in (None, SAMPLED):
            report = compare_engines(
                model, params, make, slots=2, max_len=64, block_size=8,
                chunk=4, sampling=sampling,
                engine_kwargs={"paged": {"kernel": kernel}})
            assert report.ok, (kernel, sampling, report.summary())


def test_kernel_mode_is_the_default_and_reported(served):
    cfg, model, params = served
    eng = _paged(model, params)
    assert eng.kernel == "paged" and eng.pool is None
    assert eng.view is not None
    assert eng.report()["kernel"] == "paged"
    gather = _paged(model, params, kernel="gather")
    assert gather.report()["kernel"] == "gather" and gather.view is None
    with pytest.raises(ValueError, match="kernel"):
        _paged(model, params, kernel="dense")


def test_cancel_mid_decode_under_device_page_view(served):
    """Cancel mid-decode on the kernel path: every page reference is
    released, the slot's device page-table row is cleared, and the freed
    pages are immediately reusable by a waiting request whose stream
    stays correct (end-to-end vs the contiguous oracle)."""
    cfg, model, params = served
    prompts = _prompts(cfg, n=3)
    eng = _paged(model, params)
    h0 = eng.submit(Request(rid=0, prompt=list(prompts[0]), max_new=16))
    h1 = eng.submit(Request(rid=1, prompt=list(prompts[1]), max_new=4))
    while not h0.req.out:
        eng.step()
    slot0 = h0.entry.slot
    assert (eng.view.page_table[slot0] != 0).any()
    assert h0.cancel()
    assert (eng.view.page_table[slot0] == 0).all()
    done = eng.drain()
    assert [r.rid for r in done] == [1]
    _assert_pages_clean(eng)
    # freed capacity is genuinely reusable: a fresh request decodes the
    # same stream the contiguous oracle produces for its prompt
    h2 = eng.submit(Request(rid=2, prompt=list(prompts[2]), max_new=4))
    out = list(h2.result().out)
    ref = ServeEngine(model, params, slots=2, max_len=64).run(
        [Request(rid=0, prompt=list(prompts[2]), max_new=4)])[0].out
    assert out == ref
    _assert_pages_clean(eng)


# -------------------------------------------------------------- sampling


def test_sampled_streams_identical_across_engines(served):
    """Same SamplingParams + seed => token-identical sampled streams on
    the contiguous oracle and the paged engine (the dual-environment
    verdict, extended to sampled mode)."""
    cfg, model, params = served
    prompts = _prompts(cfg)

    def make():
        return [Request(rid=i, prompt=list(p), max_new=8)
                for i, p in enumerate(prompts)]

    report = compare_engines(model, params, make, slots=2, max_len=64,
                             block_size=8, chunk=4, sampling=SAMPLED)
    assert report.ok, report.summary()
    [verdict] = report.verdicts
    assert verdict.kind == "numeric" and verdict.measured == 0.0


def test_sampled_streams_identical_across_runs(served):
    cfg, model, params = served
    prompts = _prompts(cfg)

    def one():
        eng = _paged(model, params)
        done = eng.run([Request(rid=i, prompt=list(p), max_new=8,
                                sampling=SAMPLED)
                        for i, p in enumerate(prompts)])
        return token_matrix(done, len(prompts), 8)

    a, b = one(), one()
    assert (a == b).all()
    assert (a >= 0).all()


def test_different_request_ids_decorrelate_streams(served):
    """Identical prompt + identical SamplingParams but different rids must
    draw different streams (keys fold in the request id)."""
    cfg, model, params = served
    prompt = _prompts(cfg, n=1)[0]
    eng = _paged(model, params, slots=2)
    done = eng.run([Request(rid=i, prompt=list(prompt), max_new=12,
                            sampling=SAMPLED) for i in range(2)])
    mat = token_matrix(done, 2, 12)
    assert not (mat[0] == mat[1]).all()


def test_greedy_param_is_exact_argmax(served):
    """temperature=0 through the sampling path must equal the legacy
    greedy stream (the oracle-gated behaviour is the default)."""
    cfg, model, params = served
    prompts = _prompts(cfg, n=2)

    def run_with(sampling):
        eng = _paged(model, params)
        done = eng.run([Request(rid=i, prompt=list(p), max_new=6,
                                sampling=sampling)
                        for i, p in enumerate(prompts)])
        return token_matrix(done, 2, 6)

    assert (run_with(None) == run_with(SamplingParams())).all()


def test_sampled_stream_survives_preemption(served):
    """Counter-based keys make sampled decoding replayable through
    preempt + recompute-on-readmit: the preempted request's stream equals
    an uninterrupted run's, exactly as the greedy contract."""
    cfg, model, params = served
    rng = np.random.default_rng(3)
    p_lo = rng.integers(0, cfg.vocab_size, size=12).tolist()
    p_hi = rng.integers(0, cfg.vocab_size, size=12).tolist()
    sp = SamplingParams(temperature=1.1, top_k=0, top_p=0.9, seed=5)

    base = PagedServeEngine(model, params, slots=1, max_len=48,
                            block_size=4, chunk=4)
    [alone] = base.run([Request(rid=0, prompt=list(p_lo), max_new=10,
                                sampling=sp)])

    eng = PagedServeEngine(model, params, slots=1, max_len=48,
                           block_size=4, num_blocks=8, chunk=4)
    done = eng.run(
        [Request(rid=0, prompt=list(p_lo), max_new=10, priority=0,
                 sampling=sp),
         Request(rid=1, prompt=list(p_hi), max_new=6, priority=5,
                 sampling=sp)],
        arrivals=[0.0, 5.0])
    out = {r.rid: r.out for r in done}
    assert eng.sched.stats.preemptions >= 1
    assert out[0] == alone.out


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="seed"):
        SamplingParams(seed=2**31)          # must fit the int32 lane array
    assert SamplingParams().greedy
    assert SamplingParams().describe() == "greedy"
    assert "seed=3" in SamplingParams(temperature=0.5, seed=3).describe()


def test_oversized_request_id_rejected_at_submit(served):
    cfg, model, params = served
    prompt = _prompts(cfg, n=1)[0]
    for eng in (_paged(model, params),
                ServeEngine(model, params, slots=2, max_len=64)):
        with pytest.raises(ValueError, match="int32"):
            eng.submit(Request(rid=2**31, prompt=list(prompt), max_new=4))


def test_max_new_one_finishes_at_admission_on_both_engines(served):
    """The admission-produced first token can already satisfy max_new:
    both engines must stop at exactly one token and agree."""
    cfg, model, params = served
    prompts = _prompts(cfg, n=3)

    def make():
        return [Request(rid=i, prompt=list(p), max_new=1)
                for i, p in enumerate(prompts)]

    report = compare_engines(model, params, make, slots=2, max_len=64,
                             block_size=8, chunk=4)
    assert report.ok, report.summary()
    done = ServeEngine(model, params, slots=2, max_len=64).run(make())
    assert all(len(r.out) == 1 and r.finished for r in done)


def test_all_greedy_batches_never_compile_the_sampling_program(served):
    """Greedy serving (the default) must dispatch the argmax-only fused
    program; the sampling variant compiles only once a sampled request
    actually enters a batch."""
    cfg, model, params = served
    prompts = _prompts(cfg, n=2)
    eng = _paged(model, params)
    eng.run([Request(rid=i, prompt=list(p), max_new=4)
             for i, p in enumerate(prompts)])
    assert eng._chunk_sample_fn.calls == 0
    assert eng._chunk_fn.calls > 0
    eng.run([Request(rid=9, prompt=list(prompts[0]), max_new=4,
                     sampling=SAMPLED)])
    assert eng._chunk_sample_fn.calls > 0
    assert eng.report()["compiles"] <= 1        # worst per-program count


def test_top_k_one_is_argmax_of_the_filtered_set(served):
    """top_k=1 collapses sampling to argmax regardless of temperature —
    a direct check that the rank-based filter works."""
    cfg, model, params = served
    prompts = _prompts(cfg, n=2)

    def run_with(sampling):
        eng = _paged(model, params)
        done = eng.run([Request(rid=i, prompt=list(p), max_new=5,
                                sampling=sampling)
                        for i, p in enumerate(prompts)])
        return token_matrix(done, 2, 5)

    greedy = run_with(None)
    k1 = run_with(SamplingParams(temperature=0.7, top_k=1, seed=9))
    assert (greedy == k1).all()


# ----------------------------------------------------- lifecycle tracing


def test_lifecycle_events_feed_ttft_expectations(served):
    """submit / first-token / finish / cancel events must reconstruct
    per-request latencies (the audit's TTFT evidence)."""
    from repro.audit import Evidence, Tracer

    cfg, model, params = served
    prompts = _prompts(cfg, n=3)
    tr = Tracer()
    eng = _paged(model, params, tracer=tr)
    hs = [eng.submit(Request(rid=i, prompt=list(p), max_new=4,
                             sampling=SAMPLED if i == 1 else None))
          for i, p in enumerate(prompts)]
    eng.step()
    hs[2].cancel()
    eng.drain()

    lat = Evidence(tracer=tr).request_latencies()
    assert set(lat) == {0, 1}                  # cancelled rid 2 excluded
    for rec in lat.values():
        assert rec["ttft_ticks"] > 0
        assert rec["decode_gap_ticks"] >= 1.0  # >= one tick per token
        assert rec["tokens"] == 4
    subs = {e.data["rid"]: e.data for e in tr.events("submit")}
    assert subs[1]["sampling"] != "greedy" and subs[0]["sampling"] == "greedy"
    [cancel] = tr.events("cancel")
    assert cancel.data["rid"] == 2
