"""Timeline reconstruction: exact phase decomposition of per-request
latency from the Tracer event stream.

Synthetic-record tests pin the state machine (routing, preemption,
cancellation at every lifecycle stage, in-flight requests, TTFT
clipping, cluster-mirrored duplicates) and the Chrome-trace export;
the slow property test drives every workload family through all three
engines and asserts the decomposition's defining invariant — phase
shares sum to *exactly* 1 for every closed request, in ℚ, not within
float tolerance.
"""
from fractions import Fraction

import jax
import pytest

from repro.audit import (PHASES, Tracer, attribution, build_timelines,
                         chrome_trace_bytes, to_chrome_trace)
from repro.audit.metrics import EventLog
from repro.audit.trace import TraceEvent
from repro.serve import (ClusterEngine, PagedServeEngine, Request,
                         ServeEngine, generate, smoke_specs)

GEOM = dict(slots=2, max_len=64, block_size=8, chunk=4)
MAX_NEW = 4


def _ev(seq, kind, **data):
    return TraceEvent(seq=seq, t=float(seq), kind=kind, data=data)


def _tl(records):
    """Build from a raw iterable of TraceEvents (one of the accepted
    source shapes)."""
    return build_timelines(records)


# ------------------------------------------------------ exact decomposition


def test_phases_partition_total_with_fractional_ticks():
    tls = _tl([
        _ev(0, "submit", rid=7, arrival=0.25),
        _ev(1, "admit", rid=7, slot=1, tick=3.5),
        _ev(2, "prefill-done", rid=7, slot=1, tick=5.0),
        _ev(3, "first-token", rid=7, tick=5.0),
        _ev(4, "finish", rid=7, tick=12.75, tokens_out=8),
    ])
    tl = tls[7]
    assert tl.arrival == Fraction(1, 4)
    assert tl.total() == Fraction(25, 2)
    ph = tl.phases()
    assert ph["queue_wait"] == Fraction(13, 4)
    assert ph["prefill"] == Fraction(3, 2)
    assert ph["decode"] == Fraction(31, 4)
    assert sum(ph.values()) == tl.total()          # telescoping, exact
    assert sum(tl.shares().values()) == 1          # exactly 1 in Q
    assert tl.outcome == "finished" and tl.tokens_out == 8
    assert tl.slots == [1]


def test_routing_phase_and_mirrored_duplicates_dedup():
    # cluster front door mirrors submit/route into the replica tracer;
    # feeding both streams must not double any span
    front = [
        _ev(0, "submit", rid=3, arrival=0.0),
        _ev(1, "route", rid=3, tick=2.0, replica=1),
    ]
    replica = [
        _ev(0, "route", rid=3, tick=2.0, replica=1),
        _ev(1, "admit", rid=3, slot=0, tick=4.0),
        _ev(2, "prefill-done", rid=3, slot=0, tick=5.0),
        _ev(3, "first-token", rid=3, tick=5.0),
        _ev(4, "finish", rid=3, tick=9.0),
    ]
    tl = build_timelines(front, replica)[3]
    assert tl.replica == 1
    ph = tl.phases()
    assert ph["routing"] == 2 and ph["queue_wait"] == 2
    assert ph["prefill"] == 1 and ph["decode"] == 4
    assert sum(tl.shares().values()) == 1
    # exactly one routing span despite the mirrored route event
    assert sum(1 for s in tl.spans if s.phase == "routing") == 1


def test_preempt_readmit_pays_gap_into_preempted_and_recompute_into_prefill():
    tls = _tl([
        _ev(0, "submit", rid=1, arrival=0.0),
        _ev(1, "admit", rid=1, slot=0, tick=1.0),
        _ev(2, "prefill-done", rid=1, slot=0, tick=2.0),
        _ev(3, "first-token", rid=1, tick=2.0),
        _ev(4, "preempt", rid=1, tick=4.0),
        _ev(5, "admit", rid=1, slot=1, tick=7.0),
        _ev(6, "prefill-done", rid=1, slot=1, tick=9.0),   # recompute
        _ev(7, "finish", rid=1, tick=11.0),
    ])
    tl = tls[1]
    assert tl.preemptions == 1 and tl.slots == [0, 1]
    ph = tl.phases()
    assert ph["preempted"] == 3                   # eviction -> readmission
    assert ph["prefill"] == 1 + 2                 # both segments, recompute too
    assert ph["decode"] == 2 + 2
    assert sum(ph.values()) == tl.total() == 11
    # first-token is not re-fired semantics: ttft stays at the first one
    assert tl.ttft() == 2


def test_cancel_at_each_lifecycle_stage():
    waiting = _tl([
        _ev(0, "submit", rid=0, arrival=0.0),
        _ev(1, "cancel", rid=0, tick=5.0),
    ])[0]
    assert waiting.outcome == "cancelled"
    assert waiting.phases()["queue_wait"] == waiting.total() == 5

    mid_prefill = _tl([
        _ev(0, "submit", rid=0, arrival=0.0),
        _ev(1, "admit", rid=0, slot=0, tick=2.0),
        _ev(2, "cancel", rid=0, tick=6.0),
    ])[0]
    ph = mid_prefill.phases()
    assert ph["queue_wait"] == 2 and ph["prefill"] == 4
    assert sum(mid_prefill.shares().values()) == 1

    while_preempted = _tl([
        _ev(0, "submit", rid=0, arrival=0.0),
        _ev(1, "admit", rid=0, slot=0, tick=1.0),
        _ev(2, "prefill-done", rid=0, slot=0, tick=2.0),
        _ev(3, "preempt", rid=0, tick=3.0),
        _ev(4, "cancel", rid=0, tick=8.0),
    ])[0]
    ph = while_preempted.phases()
    assert ph["preempted"] == 5 and ph["decode"] == 1
    assert sum(while_preempted.shares().values()) == 1


def test_in_flight_request_reports_open_phase_not_shares():
    tl = _tl([
        _ev(0, "submit", rid=2, arrival=0.0),
        _ev(1, "admit", rid=2, slot=0, tick=3.0),
    ])[2]
    assert tl.end is None and tl.outcome == "in-flight"
    assert tl.open_phase == "prefill" and tl.open_since == 3
    assert tl.shares() == {}                      # no total to share against
    assert "open_phase" in tl.describe()


def test_ttft_shares_clip_at_first_token():
    tl = _tl([
        _ev(0, "submit", rid=5, arrival=0.0),
        _ev(1, "admit", rid=5, slot=0, tick=6.0),
        _ev(2, "prefill-done", rid=5, slot=0, tick=8.0),
        _ev(3, "first-token", rid=5, tick=8.0),
        _ev(4, "finish", rid=5, tick=100.0),
    ])[5]
    assert tl.ttft() == 8
    ts = tl.ttft_shares()
    assert ts["queue_wait"] == Fraction(3, 4)     # 6/8, decode excluded
    assert ts["prefill"] == Fraction(1, 4)
    assert ts["decode"] == 0
    assert sum(ts.values()) == 1


def test_non_lifecycle_kinds_and_untagged_events_are_ignored():
    tls = _tl([
        _ev(0, "engine-init", engine="paged"),
        _ev(1, "submit", rid=0, arrival=0.0),
        _ev(2, "sched-admit", rid=0, tick=1.0),   # scheduler, not lifecycle
        _ev(3, "admit", rid=0, slot=0, tick=1.0),
        _ev(4, "step", tick=2.0, active=1),       # no rid
        _ev(5, "prefill-done", rid=0, slot=0, tick=2.0),
        _ev(6, "finish", rid=0, tick=4.0),
    ])
    assert list(tls) == [0]
    assert sum(tls[0].shares().values()) == 1


# --------------------------------------------------------------- attribution


def test_attribution_names_dominant_phase_of_p99_request():
    recs = []
    seq = 0
    # rid 0: fast, decode-dominant; rid 1: slow, queue-dominant
    for rid, (admit, done, fin) in {0: (1.0, 2.0, 6.0),
                                    1: (9.0, 10.0, 12.0)}.items():
        recs += [_ev(seq, "submit", rid=rid, arrival=0.0),
                 _ev(seq + 1, "admit", rid=rid, slot=0, tick=admit),
                 _ev(seq + 2, "prefill-done", rid=rid, slot=0, tick=done),
                 _ev(seq + 3, "first-token", rid=rid, tick=done),
                 _ev(seq + 4, "finish", rid=rid, tick=fin)]
        seq += 5
    att = attribution(_tl(recs))
    assert att["requests"] == 2
    assert att["p99_rid"] == 1 and att["p99_ttft_ticks"] == 10.0
    assert att["dominant_phase"] == "queue_wait"
    assert att["p99_shares"]["queue_wait"] == 0.9
    assert attribution({}) == {}


# -------------------------------------------------------------- chrome trace


def test_chrome_trace_is_valid_and_byte_deterministic():
    recs = [
        _ev(0, "submit", rid=0, arrival=0.0),
        _ev(1, "route", rid=0, tick=1.0, replica=2),
        _ev(2, "admit", rid=0, slot=1, tick=2.0),
        _ev(3, "prefill-done", rid=0, slot=1, tick=3.0),
        _ev(4, "finish", rid=0, tick=5.0),
    ]
    doc = to_chrome_trace(_tl(recs))
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    ms = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert xs and ms
    assert all(e["pid"] == 2 for e in xs)         # replica label -> pid
    assert {e["name"] for e in xs} == {"routing", "queue_wait",
                                       "prefill", "decode"}
    # off-slot spans ride the synthetic queue track; on-slot spans the slot
    by_name = {e["name"]: e for e in xs}
    assert by_name["prefill"]["tid"] == 1
    assert by_name["queue_wait"]["tid"] != 1
    assert by_name["prefill"]["ts"] == 2000.0     # tick_us scaling
    assert by_name["prefill"]["dur"] == 1000.0
    assert chrome_trace_bytes(_tl(recs)) == chrome_trace_bytes(_tl(recs))


# ---------------------------------------------- property: engines x families


def _close_all(timelines, n_requests):
    assert len(timelines) == n_requests
    for tl in timelines.values():
        assert tl.end is not None, tl.rid
        assert sum(tl.phases().values()) == tl.total()
        assert sum(tl.shares().values()) == 1
        for a, b in zip(tl.spans, tl.spans[1:]):
            assert a.end == b.start               # spans telescope
        assert all(s.phase in PHASES for s in tl.spans)


@pytest.fixture(scope="module")
def served():
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.slow
@pytest.mark.parametrize("spec_idx", [0, 1, 2],
                         ids=["chat", "rag", "agent"])
def test_shares_sum_to_exactly_one_across_engines(served, spec_idx):
    cfg, model, params = served
    spec = smoke_specs(vocab_size=cfg.vocab_size, seed=0)[spec_idx]
    trace = generate(spec)

    def reqs():
        out = trace.requests()
        for r in out:
            r.max_new = MAX_NEW
        return out

    # contiguous oracle
    tr = Tracer()
    eng = ServeEngine(model, params, slots=GEOM["slots"],
                      max_len=GEOM["max_len"], tracer=tr)
    eng.run(reqs(), arrivals=list(trace.arrivals))
    _close_all(build_timelines(tr), spec.n_requests)

    # paged engine, fed through an EventLog to cover that source shape
    tr = Tracer()
    log = EventLog()
    tr.subscribe(log.append)
    eng = PagedServeEngine(model, params, tracer=tr, **GEOM)
    eng.run(reqs(), arrivals=list(trace.arrivals))
    tls = build_timelines(log)
    _close_all(tls, spec.n_requests)
    assert all(tl.preemptions >= 0 for tl in tls.values())

    # cluster: front-door tracer + per-replica tracers merge
    tr = Tracer()
    reps = [Tracer(), Tracer()]
    eng = ClusterEngine(model, params, replicas=2, tracer=tr,
                        replica_tracers=reps, **GEOM)
    eng.run(reqs(), arrivals=list(trace.arrivals))
    tls = build_timelines(tr, *reps)
    _close_all(tls, spec.n_requests)
    assert all(tl.replica in (0, 1) for tl in tls.values())


@pytest.mark.slow
def test_cancel_of_preempted_request_reports_preempted_phase(served):
    """Regression: cancelling a PREEMPTED entry used to emit
    ``phase="waiting"``, collapsing the eviction gap into queue_wait.
    The engine must report ``phase="preempted"`` so the timeline closes
    the preempted span at the cancel tick."""
    _, model, params = served
    tr = Tracer()
    eng = PagedServeEngine(model, params, tracer=tr, slots=1, max_len=64,
                           block_size=4, num_blocks=10, chunk=4)
    lo = eng.submit(Request(rid=0, prompt=list(range(2, 14)), max_new=16,
                            priority=0), arrival=0.0)
    for _ in range(4):
        eng.step()
    eng.submit(Request(rid=1, prompt=list(range(20, 28)), max_new=6,
                       priority=5))
    eng.step()                                    # hi preempts lo
    assert lo.entry.state == "preempted"
    eng.step()                                    # let the gap have width
    assert lo.cancel()
    [ev] = tr.events("cancel")
    assert ev.data["rid"] == 0 and ev.data["phase"] == "preempted"
    eng.drain()
    tls = build_timelines(tr)
    assert tls[0].outcome == "cancelled" and tls[0].preemptions == 1
    last = tls[0].spans[-1]
    assert last.phase == "preempted"              # gap attributed correctly
    assert last.end == tls[0].end
    assert sum(tls[0].shares().values()) == 1
    assert tls[1].outcome == "finished"


@pytest.mark.slow
def test_cancel_and_preempt_paths_stay_exact(served):
    _, model, params = served
    tr = Tracer()
    eng = PagedServeEngine(model, params, tracer=tr, slots=1, max_len=64,
                           block_size=8, chunk=4)
    h_run = eng.submit(Request(rid=0, prompt=[3, 4, 5, 6], max_new=6),
                       arrival=0.0)
    h_wait = eng.submit(Request(rid=1, prompt=[7, 8, 9], max_new=4),
                        arrival=0.0)
    for _ in range(3):
        eng.step()
    assert eng.cancel(h_wait)                     # cancelled while queued
    while not h_run.req.finished:
        eng.step()
    tls = build_timelines(tr)
    assert tls[0].outcome == "finished"
    assert tls[1].outcome == "cancelled"
    wait_ph = tls[1].phases()
    assert wait_ph["queue_wait"] == tls[1].total()   # never admitted
    for tl in tls.values():
        assert sum(tl.shares().values()) == 1
