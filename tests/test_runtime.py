"""Fault-tolerance layer: checkpoint/restart (incl. elastic resharding and
corruption detection), health/failure protocol, straggler tracking, data
pipeline determinism."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, DataPipeline, batch_at
from repro.launch.mesh import mesh_of
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.health import (HealthRegistry, HostState, plan_restart)
from repro.runtime.straggler import StragglerTracker


# ---------------------------------------------------------- checkpoint


def _state():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = _state()
    mgr.save(7, state)
    assert mgr.latest_step() == 7
    restored = mgr.restore(None, like=jax.tree.map(jnp.zeros_like, state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_commit_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _state())
    assert not list(tmp_path.glob("*.tmp"))


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, _state())
    shard = next((tmp_path / "step_00000003").glob("host_*.npz"))
    data = bytearray(shard.read_bytes())
    data[len(data) // 2] ^= 0xFF
    shard.write_bytes(bytes(data))
    with pytest.raises(Exception):
        mgr.restore(3, like=jax.tree.map(jnp.zeros_like, _state()))


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state())
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Restore applies whatever shardings the NEW mesh provides — the
    elastic-rescale path (single-device here; the semantics are the
    device_put target, which is mesh-independent)."""
    mgr = CheckpointManager(tmp_path)
    state = _state()
    mgr.save(5, state)
    mesh = mesh_of((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, state)
    restored = mgr.restore(5, like=state, shardings=shardings)
    assert restored["params"]["w"].sharding == sh


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _state())
    wrong = {"params": {"w": jnp.zeros((2, 2), jnp.bfloat16),
                        "b": jnp.zeros((4,), jnp.float32)},
             "step": jnp.asarray(0, jnp.int32)}
    with pytest.raises(ValueError):
        mgr.restore(1, like=wrong)


# -------------------------------------------------------------- health


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_failure_detection_and_elastic_rebuild():
    clock = FakeClock()
    reg = HealthRegistry(n_hosts=4, suspect_s=10, dead_s=60, clock=clock)
    assert reg.healthy

    # host 2 goes silent; others keep beating
    for t in range(0, 70, 5):
        clock.t = float(t)
        for h in (0, 1, 3):
            reg.beat(h)
    states = reg.sweep()
    assert states[2] == HostState.DEAD
    assert reg.survivors == [0, 1, 3]

    plan = plan_restart(reg, last_checkpoint=100, min_hosts=3,
                        grace_s=30, silence_s=70)
    assert plan.action == "rebuild"
    assert plan.restore_step == 100
    assert plan.mesh_hosts == [0, 1, 3]


def test_transient_suspect_waits_then_recovers():
    clock = FakeClock()
    reg = HealthRegistry(n_hosts=2, suspect_s=10, dead_s=60, clock=clock)
    clock.t = 15.0
    reg.beat(0)  # host 1 silent for 15s -> suspect
    plan = plan_restart(reg, None, min_hosts=2, grace_s=30, silence_s=15)
    assert plan.action == "wait"
    reg.beat(1)  # heartbeat returns
    assert reg.healthy


def test_too_few_survivors_waits():
    clock = FakeClock()
    reg = HealthRegistry(n_hosts=2, suspect_s=1, dead_s=5, clock=clock)
    clock.t = 10.0
    reg.beat(0)
    plan = plan_restart(reg, 42, min_hosts=2, grace_s=1, silence_s=10)
    assert plan.action == "wait"
    assert "survivors" in plan.reason


# ------------------------------------------------------------ straggler


def test_straggler_flagging():
    tr = StragglerTracker(n_hosts=4, patience=3)
    flagged = []
    for _ in range(10):
        flagged = tr.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 2.5})
    assert flagged == [3]
    assert tr.fleet_efficiency() < 0.75


def test_no_false_positives_on_uniform_fleet():
    tr = StragglerTracker(n_hosts=8)
    rng = np.random.default_rng(0)
    for _ in range(20):
        times = {h: 1.0 + 0.05 * rng.standard_normal() for h in range(8)}
        assert tr.observe(times) == []
    assert tr.fleet_efficiency() > 0.9


# ----------------------------------------------------------------- data


def test_data_determinism_and_host_sharding():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    b1 = batch_at(cfg, 5)
    b2 = batch_at(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # different hosts see different data
    h1 = batch_at(DataConfig(100, 16, 8, n_hosts=2, host_id=1), 5)
    assert not np.array_equal(b1["tokens"][:4], h1["tokens"])


def test_pipeline_resume_mid_epoch():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=4)
    p = DataPipeline(cfg, start_step=0)
    seq = [next(p) for _ in range(5)]
    p.close()
    p2 = DataPipeline(cfg, start_step=3)
    step, batch = next(p2)
    p2.close()
    assert step == 3
    np.testing.assert_array_equal(batch["tokens"], seq[3][1]["tokens"])


def test_elastic_restore_across_device_counts(tmp_path):
    """End-to-end elastic rescale: checkpoint written under a 4-device
    mesh restores onto an 8-device mesh with different shardings and the
    training loss continues identically (subprocess provides the multi-
    device runtimes; the checkpoint format stores global arrays)."""
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, numpy as np
        from repro.configs import ALL_ARCHS, reduced, ShapeConfig
        from repro.configs.base import RunConfig, TrainConfig
        from repro.launch.bind import batch_shardings, state_shardings
        from repro.launch.mesh import mesh_of
        from repro.models import build
        from repro.parallel import bind, rules_for
        from repro.runtime.checkpoint import CheckpointManager
        from repro.train.step import init_train_state, make_train_step

        cfg = reduced(ALL_ARCHS["deepseek-7b"])
        model = build(cfg)
        shape = ShapeConfig("t", "train", 32, 4)
        run = RunConfig(model=cfg, shape=shape, train=TrainConfig())
        step_fn = make_train_step(model, run)
        key = jax.random.PRNGKey(0)
        batch = model.sample_batch(shape, key)
        mgr = CheckpointManager(r"{tmp_path}")

        def one_step(mesh, restore):
            with bind(mesh, rules_for(run)):
                st_sh = state_shardings(model, mesh)
                b_sh = batch_shardings(model, shape, mesh)
                state = init_train_state(model, key)
                if restore:
                    state = mgr.restore(None, like=state, shardings=st_sh)
                state = jax.device_put(state, st_sh)
                # fresh callable per mesh binding: older jax keys the trace
                # cache on function identity only, so reusing step_fn would
                # replay mesh-A sharding constraints under mesh B
                jitted = jax.jit(lambda st, b: step_fn(st, b),
                                 in_shardings=(st_sh, b_sh),
                                 out_shardings=(st_sh, None))
                state, m = jitted(state, jax.device_put(batch, b_sh))
                return state, float(m["loss"])

        mesh4 = mesh_of((2, 2), ("data", "model"))
        mesh8 = mesh_of((2, 4), ("data", "model"))
        state, loss_a = one_step(mesh4, restore=False)
        mgr.save(1, state)
        # continue on the 4-device mesh vs restore onto the 8-device mesh
        _, loss_4 = one_step(mesh4, restore=True)
        _, loss_8 = one_step(mesh8, restore=True)
        assert abs(loss_4 - loss_8) < 2e-2, (loss_4, loss_8)
        print("ELASTIC OK", loss_4, loss_8)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC OK" in out.stdout
