"""Pallas paged-attention kernel vs the pure-JAX page-table reference.

The kernel-parity suite for the serving stack's paged decode pathway
(`kernels/paged_attention.py`): property-based parity in interpret mode
across head counts, page sizes, ragged last pages and GQA ratios, the
edge geometries (single-token sequence, exactly-full last page), the
no-aliasing guarantee for refcount-shared prefix pages, and the kernel
driven through the full `PagedServeEngine` against the gather fallback.

Everything runs the real kernel body — interpret mode off-accelerator
(forced by the session fixture in conftest), native Mosaic on TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # invariants still run via the conftest property loop
    from conftest import given, settings, st

from repro.kernels.paged_attention import (paged_attention_pallas,
                                           paged_attention_ref)

pytestmark = pytest.mark.kernel

RNG = np.random.default_rng(1234)


def _case(b, c, kv, g, hd, bs, n_pages, num_blocks, pos, n_new, *,
          dtype=jnp.float32, seed=0):
    """Build one paged-attention problem: random pool, a random
    *permutation* page table (so physical order never coincides with
    logical order by accident), per-lane pos/n_new."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, c, kv, g, hd)), dtype)
    kp = jnp.asarray(rng.standard_normal((num_blocks, bs, kv, hd)), dtype)
    vp = jnp.asarray(rng.standard_normal((num_blocks, bs, kv, hd)), dtype)
    perm = rng.permutation(num_blocks)[:b * n_pages]
    pt = jnp.asarray(perm.reshape(b, n_pages).astype(np.int32))
    return (q, kp, vp, pt, jnp.asarray(pos, jnp.int32),
            jnp.asarray(n_new, jnp.int32))


def _assert_parity(args, *, rtol=2e-5, atol=2e-5):
    """Kernel (interpret) vs reference on every lane's valid rows
    (rows >= n_new are garbage both sides discard by contract)."""
    q, kp, vp, pt, pos, n_new = args
    out = paged_attention_pallas(q, kp, vp, pt, pos, n_new, interpret=True)
    ref = paged_attention_ref(q, kp, vp, pt, pos, n_new)
    for b in range(q.shape[0]):
        n = int(n_new[b])
        np.testing.assert_allclose(
            np.asarray(out, np.float32)[b, :n],
            np.asarray(ref, np.float32)[b, :n],
            rtol=rtol, atol=atol,
            err_msg=f"lane {b}: pos={int(pos[b])} n_new={n}")


# ----------------------------------------------------------- property sweep


@given(st.sampled_from([1, 2]),            # kv heads
       st.sampled_from([1, 2, 4]),         # GQA group (q heads per kv)
       st.sampled_from([4, 8, 16]),        # page size
       st.integers(1, 4),                  # chunk C
       st.integers(0, 10**9),              # case seed
       st.integers(0, 10**9))              # pos/n_new seed
@settings(max_examples=12, deadline=None)
def test_kernel_matches_gather_reference(kv, g, bs, c, seed, state_seed):
    """Parity across head counts, page sizes, GQA ratios, and random
    ragged per-lane (pos, n_new) states — including idle lanes."""
    b, hd, n_pages = 2, 32, 4
    rng = np.random.default_rng(state_seed)
    # lane state: pos + n_new must fit the table; n_new <= c; allow 0
    n_new = rng.integers(0, c + 1, size=b)
    pos = np.array([rng.integers(0, n_pages * bs - max(int(n), 1) + 1)
                    for n in n_new])
    args = _case(b, c, kv, g, hd, bs, n_pages, num_blocks=3 * n_pages,
                 pos=pos, n_new=n_new, seed=seed)
    _assert_parity(args)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_kernel_dtype_sweep(dtype, tol):
    args = _case(2, 4, 2, 2, 32, 8, 4, num_blocks=12,
                 pos=[13, 27], n_new=[4, 1], dtype=dtype, seed=7)
    _assert_parity(args, rtol=tol, atol=tol)


# ------------------------------------------------------------------- edges


def test_single_token_sequence():
    """pos=0, n_new=1: the kernel's smallest case — one valid row in one
    page, every other position masked."""
    args = _case(2, 4, 2, 2, 32, 8, 4, num_blocks=8,
                 pos=[0, 0], n_new=[1, 1], seed=3)
    _assert_parity(args)
    # and the output equals plain attention over that single position:
    # softmax over one element is 1, so out == v at the row the table maps
    q, kp, vp, pt, pos, n_new = args
    out = paged_attention_pallas(q, kp, vp, pt, pos, n_new, interpret=True)
    for b in range(2):
        want = np.asarray(vp)[int(pt[b, 0]), 0]          # [kv, hd]
        got = np.asarray(out)[b, 0]                      # [kv, g, hd]
        np.testing.assert_allclose(got, np.repeat(
            want[:, None], got.shape[1], axis=1), rtol=2e-5, atol=2e-5)


def test_exactly_full_last_page():
    """pos + n_new landing exactly on a page boundary must not read the
    following (unallocated / stale) page."""
    bs, n_pages = 8, 4
    for total_pages in (1, 2, 4):
        pos = total_pages * bs - 2
        args = _case(2, 2, 2, 2, 32, bs, n_pages, num_blocks=12,
                     pos=[pos, pos], n_new=[2, 2], seed=11 + total_pages)
        _assert_parity(args)


def test_ragged_last_page_lengths():
    """Every tail length of the last page, exercised one by one."""
    bs = 8
    for tail in range(1, bs + 1):
        pos = bs + tail - 1                  # last valid row index
        args = _case(2, 1, 2, 2, 32, bs, 4, num_blocks=12,
                     pos=[pos, pos], n_new=[1, 1], seed=100 + tail)
        _assert_parity(args)


def test_masked_rows_are_finite():
    """Idle lanes (n_new=0) and garbage chunk rows must come out finite —
    the engine discards them, but NaNs would poison donated buffers."""
    args = _case(2, 4, 2, 2, 32, 8, 4, num_blocks=8,
                 pos=[0, 5], n_new=[0, 2], seed=5)
    q, kp, vp, pt, pos, n_new = args
    out = paged_attention_pallas(q, kp, vp, pt, pos, n_new, interpret=True)
    assert np.isfinite(np.asarray(out, np.float32)).all()


# -------------------------------------------- shared prefix pages: no alias


def test_shared_prefix_pages_are_never_written():
    """Two slots whose page tables share refcounted prefix pages must not
    alias writes: the chunk scatter targets each lane's private pages
    only, and the shared page's bits stay identical."""
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.models.attention import paged_chunk_decode_attention

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    p = jax.tree.map(lambda a: a[0], params["layers"])["attn"]
    bs, c, nb = 8, 4, 6
    rng = np.random.default_rng(0)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    kp = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)), jnp.bfloat16)
    vp = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)), jnp.bfloat16)
    # both lanes share physical page 0 as their logical block 0; private
    # continuation pages 1 and 2 respectively
    pt = jnp.asarray(np.array([[0, 1, 0], [0, 2, 0]], np.int32))
    x = jnp.asarray(rng.standard_normal((2, c, cfg.d_model)), jnp.bfloat16)
    pos = jnp.asarray([bs, bs], jnp.int32)     # writes start past page 0
    n_new = jnp.asarray([c, c], jnp.int32)

    before = {i: np.asarray(kp[i]).copy() for i in range(nb)}
    _, kp2, vp2 = paged_chunk_decode_attention(cfg, p, x, kp, vp, pt,
                                               pos, n_new)
    after = np.asarray(kp2)
    # the shared page is bit-identical; each private page changed exactly
    # its first c rows; everything else untouched
    assert (after[0] == before[0]).all(), "shared prefix page was written"
    for lane, page in ((0, 1), (1, 2)):
        assert not (after[page][:c] == before[page][:c]).all()
        assert (after[page][c:] == before[page][c:]).all()
    for untouched in (3, 4, 5):
        assert (after[untouched] == before[untouched]).all()


def test_two_slots_reading_shared_pages_agree_with_ref():
    """Shared pages attended by two lanes at once (the zero-copy prefix
    reuse case) — parity with the gather reference."""
    b, c, kv, g, hd, bs, n_pages = 2, 2, 2, 2, 32, 8, 4
    rng = np.random.default_rng(21)
    q = jnp.asarray(rng.standard_normal((b, c, kv, g, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((10, bs, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((10, bs, kv, hd)), jnp.float32)
    # both lanes share pages 4 and 7 as blocks 0-1, then diverge
    pt = jnp.asarray(np.array([[4, 7, 1, 2], [4, 7, 5, 6]], np.int32))
    pos = jnp.asarray([2 * bs + 3, 3 * bs + 1], jnp.int32)
    n_new = jnp.asarray([2, 1], jnp.int32)
    _assert_parity((q, kp, vp, pt, pos, n_new))


# --------------------------------------------------- kernel through engine


@pytest.mark.slow
def test_kernel_through_engine_matches_gather_fallback():
    """Force the Pallas kernel (interpret mode) onto the live serving
    path and hold the full engine to the gather fallback's streams —
    the kernel analogue of the engine oracle, on a seeded trace."""
    from repro.configs import ALL_ARCHS, reduced
    from repro.kernels import ops
    from repro.models import build
    from repro.serve.engine import PagedServeEngine, Request, token_matrix

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    params = build(cfg).init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, size=16).tolist()
    tails = [rng.integers(0, cfg.vocab_size, size=3 + i).tolist()
             for i in range(3)]

    def make():
        return [Request(rid=i, prompt=shared + tails[i], max_new=4)
                for i in range(3)]

    def run(force_kernel):
        # fresh Model per mode: the jitted paged program is cached on the
        # model instance and bakes the dispatch decision in at trace time
        model = build(cfg)
        prev = ops.FORCE_PAGED_KERNEL
        ops.FORCE_PAGED_KERNEL = force_kernel
        try:
            eng = PagedServeEngine(model, params, slots=2, max_len=48,
                                   block_size=8, chunk=4)
            mat = token_matrix(eng.run(make()), 3, 4)
        finally:
            ops.FORCE_PAGED_KERNEL = prev
        eng.alloc.check()
        assert eng.pstats.cached_tokens > 0     # prefix reuse really on
        return mat

    kernel_mat = run(True)
    gather_mat = token_matrix(
        PagedServeEngine(build(cfg), params, slots=2, max_len=48,
                         block_size=8, chunk=4,
                         kernel="gather").run(make()), 3, 4)
    assert (kernel_mat >= 0).all()
    assert (kernel_mat == gather_mat).all()
    # and the ref-dispatch default (CPU) agrees too
    ref_mat = run(False)
    assert (ref_mat == gather_mat).all()
