"""Property tests on the system's invariants (hypothesis when available,
otherwise the deterministic property loop from conftest)."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # invariants still run via the conftest property loop
    from conftest import given, settings, st

from repro.configs.base import MeshConfig, ModelConfig
from repro.core import inspector
from repro.models.layers import HeadGeom, ceil_mult, cross_entropy
from repro.optim import adamw
from repro.configs.base import TrainConfig

SETTINGS = dict(max_examples=40, deadline=None)


# ------------------------------------------------------------ HeadGeom


@given(st.integers(1, 64), st.integers(1, 64), st.sampled_from([1, 2, 4, 8, 16]))
@settings(**SETTINGS)
def test_head_geom_invariants(kv, group, tp):
    """For every GQA geometry: the padded run layout must (a) be divisible
    by tp, (b) contain every real head, (c) keep q-head -> kv-head grouping."""
    h = kv * group
    geom = HeadGeom(n_heads=h, n_kv=kv, head_dim=64, tp=tp)
    assert geom.h_run % tp == 0
    assert geom.h_run >= h
    assert geom.g_pad >= geom.group
    assert geom.h_run == geom.n_kv * geom.g_pad
    # real head i = (k, g) lives at flat position k*g_pad + g < h_run
    for k in range(kv):
        for g in range(group):
            assert k * geom.g_pad + g < geom.h_run


@given(st.integers(1, 1000), st.integers(1, 256))
@settings(**SETTINGS)
def test_ceil_mult(x, m):
    r = ceil_mult(x, m)
    assert r % m == 0 and r >= x and r - x < m


# ------------------------------------------------------- cross entropy


@given(st.integers(2, 8), st.integers(4, 32), st.integers(0, 200))
@settings(**SETTINGS)
def test_cross_entropy_padded_vocab_invariance(b, v, pad):
    """Padding the vocab dim must not change the loss (padded logits are
    masked): the invariant the Megatron-style padded embedding relies on."""
    rng = np.random.default_rng(b * 1000 + v)
    logits = jnp.asarray(rng.standard_normal((b, 4, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, 4)), jnp.int32)
    loss1, _ = cross_entropy(logits, labels, v)
    padded = jnp.pad(logits, ((0, 0), (0, 0), (0, pad)),
                     constant_values=123.0)  # garbage in padding
    loss2, _ = cross_entropy(padded, labels, v)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-5)


# ------------------------------------------------------------- optimizer


@given(st.floats(1e-5, 1e-2), st.integers(1, 5))
@settings(max_examples=10, deadline=None)
def test_adamw_descends_quadratic(lr, steps):
    """AdamW must reduce a convex quadratic from any small LR."""
    tc = TrainConfig(learning_rate=lr, warmup_steps=0, total_steps=1000,
                     weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([3.0, -2.0], jnp.float32)}
    state = adamw.init(params)
    loss0 = float(jnp.sum(params["w"] ** 2))
    for _ in range(steps):
        grads = {"w": 2 * state.master["w"]}
        params, state, _ = adamw.apply(tc, state, grads, params)
    assert float(jnp.sum(state.master["w"] ** 2)) < loss0


def test_adamw_grad_clip_bounds_update():
    tc = TrainConfig(learning_rate=1.0, warmup_steps=0, grad_clip=1.0)
    params = {"w": jnp.zeros((3,), jnp.float32)}
    state = adamw.init(params)
    grads = {"w": jnp.asarray([1e6, -1e6, 1e6], jnp.float32)}
    clipped, gnorm = adamw.clip_by_global_norm(grads, tc.grad_clip)
    assert float(jnp.linalg.norm(clipped["w"])) <= 1.0 + 1e-5
    assert float(gnorm) > 1e5


# ------------------------------------------------------------ inspector


@given(st.integers(1, 64), st.sampled_from(["all-reduce", "all-gather",
                                            "reduce-scatter",
                                            "collective-permute"]),
       st.integers(2, 512))
@settings(**SETTINGS)
def test_ring_model_bounds(payload_mib, kind, g):
    """Per-device moved bytes are bounded by 2× payload for any group."""
    op = inspector.CollectiveOp("x", kind, payload_mib * 2**20, g, "main")
    assert 0 < op.moved_bytes <= 2 * payload_mib * 2**20


@given(st.integers(1, 30), st.integers(1, 10))
@settings(**SETTINGS)
def test_hlo_cost_trip_multiplication(trips, dim):
    """A dot inside a known-trip-count while must be counted trips times."""
    n = dim * 8
    hlo = f"""HloModule m

%body (p: (s32[], f32[{n},{n}])) -> (s32[], f32[{n},{n}]) {{
  %p = (s32[], f32[{n},{n}]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[{n},{n}] get-tuple-element(%p), index=1
  %d = f32[{n},{n}] dot(%g1, %g1), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
  ROOT %t = (s32[], f32[{n},{n}]) tuple(%g0, %d)
}}

%cond (p: (s32[], f32[{n},{n}])) -> pred[] {{
  %p = (s32[], f32[{n},{n}]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant({trips})
  ROOT %lt = pred[] compare(%g0, %c), direction=LT
}}

ENTRY %main (a: f32[{n},{n}]) -> f32[{n},{n}] {{
  %a = f32[{n},{n}] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[{n},{n}]) tuple(%z, %a)
  %w = (s32[], f32[{n},{n}]) while(%t0), condition=%cond, body=%body, backend_config={{"known_trip_count":{{"n":"{trips}"}}}}
  ROOT %r = f32[{n},{n}] get-tuple-element(%w), index=1
}}
"""
    cost = inspector.hlo_cost(hlo)
    expect = 2.0 * n * n * n * trips
    assert abs(cost["dot_flops"] - expect) / expect < 1e-6


# -------------------------------------------------------------- mesh


@given(st.sampled_from([(16, 16), (2, 16, 16), (4, 8), (2, 4, 4)]))
@settings(max_examples=8, deadline=None)
def test_mesh_config_axis_arithmetic(shape):
    axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
    mc = MeshConfig(shape, axes)
    assert mc.n_devices == int(np.prod(shape))
    assert mc.axis_size("model") == shape[-1]
    assert mc.axis_size("nonexistent") == 1
