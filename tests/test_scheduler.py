"""Deterministic scheduler tests on a synthetic clock, plus the
evict-and-recompute equivalence proof on the real paged engine."""
import jax
import numpy as np
import pytest

from repro.serve.scheduler import (DONE, PREEMPTED, RUNNING, WAITING, Plan,
                                   Scheduler)


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _sched(slots=1):
    clock = Clock()
    return Scheduler(slots=slots, clock=clock), clock


def _no_cost(_entry) -> int:
    return 0


# ----------------------------------------------------------------- FCFS


def test_fcfs_ordering_within_priority():
    sched, _ = _sched(slots=1)
    a = sched.submit("a")
    b = sched.submit("b")
    c = sched.submit("c")
    admitted = []
    for _ in range(3):
        plan = sched.schedule(free_slots=1, free_pages=0, cost_fn=_no_cost)
        assert not plan.preempt
        [e] = plan.admit                      # strict head-of-line
        sched.mark_running(e, slot=0, held_pages=0)
        admitted.append(e)
        sched.mark_done(e)
    assert [e.req for e in admitted] == ["a", "b", "c"]
    assert all(e.state == DONE for e in (a, b, c))


def test_priority_beats_submission_order():
    sched, _ = _sched(slots=1)
    lo = sched.submit("lo", priority=0)
    hi = sched.submit("hi", priority=3)
    plan = sched.schedule(free_slots=1, free_pages=0, cost_fn=_no_cost)
    assert plan.admit[0] is hi
    assert lo.state == WAITING


def test_arrivals_gate_on_the_synthetic_clock():
    sched, clock = _sched(slots=2)
    late = sched.submit("late", arrival=10.0)
    plan = sched.schedule(free_slots=2, free_pages=0, cost_fn=_no_cost)
    assert not plan.admit
    clock.t = 10.0
    plan = sched.schedule(free_slots=2, free_pages=0, cost_fn=_no_cost)
    assert plan.admit == [late]


def test_page_cost_blocks_admission_and_head_of_line_holds():
    """A request that does not fit page-wise blocks everything behind it
    (no FCFS bypass), even with free slots."""
    sched, _ = _sched(slots=2)
    big = sched.submit("big")
    sched.submit("small")
    cost = {"big": 8, "small": 1}
    plan = sched.schedule(free_slots=2, free_pages=4,
                          cost_fn=lambda e: cost[e.req])
    assert not plan.admit and not plan.preempt
    plan = sched.schedule(free_slots=2, free_pages=9,
                          cost_fn=lambda e: cost[e.req])
    assert [e.req for e in plan.admit] == ["big", "small"]
    assert plan.admit[0] is big


# ------------------------------------------------------------ preemption


def test_preempts_lowest_priority_most_recent_victim():
    sched, _ = _sched(slots=2)
    v1 = sched.submit("v1", priority=0)
    v2 = sched.submit("v2", priority=0)
    for e, slot in ((v1, 0), (v2, 1)):
        sched.mark_running(e, slot=slot, held_pages=2)
    hi = sched.submit("hi", priority=5)
    plan = sched.schedule(free_slots=0, free_pages=0,
                          cost_fn=lambda e: 2)
    assert plan.admit == [hi]
    assert plan.preempt == [v2]               # most recent lower-pri victim
    sched.mark_preempted(v2)
    assert v2.state == PREEMPTED and v2.preemptions == 1
    assert v2 in sched.waiting                # recompute on readmission


def test_victim_ordering_lowest_priority_then_most_recent_first():
    """Victim pool order is (priority asc, seq desc): among candidates of
    the lowest priority the most recently submitted goes first (cheapest
    recompute), and higher-but-still-lower priorities are only reached
    once the tier below is exhausted."""
    sched, _ = _sched(slots=3)
    v_old = sched.submit("v_old", priority=0)       # seq 0
    v_mid = sched.submit("v_mid", priority=1)       # seq 1
    v_new = sched.submit("v_new", priority=0)       # seq 2
    for e, slot in ((v_old, 0), (v_mid, 1), (v_new, 2)):
        sched.mark_running(e, slot=slot, held_pages=2)

    sched.submit("hi", priority=5)
    plan = sched.schedule(free_slots=0, free_pages=0, cost_fn=lambda e: 6)
    # needs 3 victims' pages: pri-0 tier first (newest before oldest),
    # then the pri-1 entry
    assert [e.req for e in plan.preempt] == ["v_new", "v_old", "v_mid"]


def test_victims_must_be_strictly_lower_priority_even_mid_pick():
    """A candidate that exhausts the strictly-lower tier stops there: it
    must not extend the victim list with equal-priority entries, and a
    partial pick that cannot buy admission is rolled back."""
    sched, _ = _sched(slots=2)
    lo = sched.submit("lo", priority=0)
    peer = sched.submit("peer", priority=1)
    for e, slot in ((lo, 0), (peer, 1)):
        sched.mark_running(e, slot=slot, held_pages=2)

    sched.submit("cand", priority=1)
    # evicting lo alone frees 2 pages; cand needs 4 and peer (equal
    # priority) is untouchable -> no admission AND no futile eviction
    plan = sched.schedule(free_slots=0, free_pages=0, cost_fn=lambda e: 4)
    assert not plan.admit and not plan.preempt
    assert lo.state == RUNNING and peer.state == RUNNING

    # with a feasible demand the strictly-lower victim is taken alone
    plan = sched.schedule(free_slots=0, free_pages=0, cost_fn=lambda e: 2)
    assert [e.req for e in plan.admit] == ["cand"]
    assert [e.req for e in plan.preempt] == ["lo"]


def test_never_preempts_equal_or_higher_priority():
    sched, _ = _sched(slots=1)
    run = sched.submit("run", priority=2)
    sched.mark_running(run, slot=0, held_pages=1)
    sched.submit("same", priority=2)
    plan = sched.schedule(free_slots=0, free_pages=0, cost_fn=_no_cost)
    assert not plan.admit and not plan.preempt
    assert run.state == RUNNING


def test_plan_attributes_victims_to_their_candidate():
    """``Plan.victims`` maps each admitted candidate to the victims whose
    pages buy that specific admission, so the engine can commit each
    preemption only when its candidate's admission succeeds."""
    sched, _ = _sched(slots=2)
    v1 = sched.submit("v1", priority=0)
    v2 = sched.submit("v2", priority=0)
    for e, slot in ((v1, 0), (v2, 1)):
        sched.mark_running(e, slot=slot, held_pages=2)
    hi1 = sched.submit("hi1", priority=5)
    hi2 = sched.submit("hi2", priority=4)
    plan = sched.schedule(free_slots=0, free_pages=0, cost_fn=lambda e: 2)
    assert plan.admit == [hi1, hi2]
    assert plan.preempt == [v2, v1]           # aggregate order preserved
    assert plan.victims == {hi1.seq: [v2], hi2.seq: [v1]}
    # a candidate admitted without victims gets no entry
    sched2, _ = _sched(slots=1)
    only = sched2.submit("only")
    plan2 = sched2.schedule(free_slots=1, free_pages=4, cost_fn=lambda e: 1)
    assert plan2.admit == [only] and plan2.victims == {}


def test_failed_admission_commits_no_preemption():
    """The engine's commit-on-success contract: when the exact budget
    check inside ``_admit`` fails (pages consumed intra-tick that the
    plan could not see), NO victim is preempted — running work is never
    flushed for an admission that does not happen."""
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import PagedServeEngine, Request

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    eng = PagedServeEngine(model, params, slots=1, max_len=64, block_size=4,
                           num_blocks=10, chunk=4)
    lo = eng.submit(Request(rid=0, prompt=rng.integers(0, 40, 12).tolist(),
                            max_new=16, priority=0), arrival=0.0)
    for _ in range(4):                        # prefill done, decoding
        eng.step()
    assert lo.entry.state == RUNNING and lo.entry.held_pages == 7
    hi = eng.submit(Request(rid=1, prompt=rng.integers(40, 80, 20).tolist(),
                            max_new=12, priority=5))    # needs 8 pages

    # simulate intra-tick consumption: pin every free page so the
    # victim's 7 pages alone cannot cover the candidate's 8
    pins = [eng.alloc.alloc() for _ in range(eng.alloc.num_free)]
    retries_before = eng.pstats.admit_retries
    assert not eng._admit(hi.entry, (lo.entry,))
    assert eng.sched.stats.preemptions == 0   # victim untouched
    assert lo.entry.state == RUNNING and hi.entry.state == WAITING
    assert eng.pstats.admit_retries == retries_before + 1

    # with the pins released the same admission succeeds and the victim
    # is preempted exactly once, inside the successful _admit
    for bid in pins:
        eng.alloc.decref(bid)
    assert eng._admit(hi.entry, (lo.entry,))
    assert eng.sched.stats.preemptions == 1
    assert lo.entry.state == PREEMPTED and hi.entry.state == RUNNING
    eng.drain()
    assert len(lo.req.out) == 16 and len(hi.req.out) == 12
    eng.alloc.check()
    eng.host.check()


def test_preempted_entry_resumes_before_later_arrivals():
    """A preempted request keeps its submission order: it readmits ahead
    of same-priority requests submitted after it."""
    sched, _ = _sched(slots=1)
    first = sched.submit("first", priority=0)
    sched.mark_running(first, slot=0, held_pages=1)
    sched.submit("second", priority=0)
    hi = sched.submit("hi", priority=9)
    plan = sched.schedule(free_slots=0, free_pages=0, cost_fn=_no_cost)
    assert plan.admit == [hi] and plan.preempt == [first]
    sched.mark_preempted(first)
    sched.mark_running(hi, slot=0, held_pages=1)
    sched.mark_done(hi)
    plan = sched.schedule(free_slots=1, free_pages=1, cost_fn=_no_cost)
    assert plan.admit[0] is first             # ahead of "second"


def test_no_futile_preemption_when_admission_stays_impossible():
    """Victims are only evicted if that actually buys the admission: a
    request too big to ever fit must not flush lower-priority work."""
    sched, _ = _sched(slots=1)
    lo = sched.submit("lo", priority=0)
    sched.mark_running(lo, slot=0, held_pages=1)
    sched.submit("huge", priority=5)
    plan = sched.schedule(free_slots=0, free_pages=0, cost_fn=lambda e: 100)
    assert not plan.admit and not plan.preempt
    assert lo.state == RUNNING


# ----------------------------------- evict-and-recompute on the real engine


def test_preempted_request_output_matches_uninterrupted_run():
    """The scheduler's recompute-on-readmit contract, proven on the real
    engine: a low-priority request preempted by a high-priority arrival
    must produce exactly the token stream of an uninterrupted run (greedy
    decoding is deterministic; readmission re-prefills prompt + generated
    tokens, prefix-cache hits included)."""
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import PagedServeEngine, Request

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    p_lo = rng.integers(0, cfg.vocab_size, size=12).tolist()
    p_hi = rng.integers(0, cfg.vocab_size, size=12).tolist()

    # uninterrupted baseline: same geometry, ample pages, alone
    base = PagedServeEngine(model, params, slots=1, max_len=48,
                            block_size=4, chunk=4)
    [alone] = base.run([Request(rid=0, prompt=list(p_lo), max_new=10)])

    # constrained: one slot, few pages; the high-priority arrival preempts
    eng = PagedServeEngine(model, params, slots=1, max_len=48,
                           block_size=4, num_blocks=8, chunk=4)
    done = eng.run(
        [Request(rid=0, prompt=list(p_lo), max_new=10, priority=0),
         Request(rid=1, prompt=list(p_hi), max_new=6, priority=5)],
        arrivals=[0.0, 5.0])
    out = {r.rid: r.out for r in done}

    assert eng.sched.stats.preemptions >= 1
    assert eng.sched.stats.readmissions >= 1
    assert out[0] == alone.out                # token-for-token equivalence
    assert len(out[1]) == 6
    eng.alloc.check()
    assert eng.alloc.in_use == len(eng.prefix)   # only cache refs remain


def test_unplaceable_request_rejected_at_submit():
    """A request that cannot fit the pool even fully recomputed fails at
    submit() — once queued it would starve the strict head-of-line queue
    without ever becoming admissible."""
    from repro.configs import ALL_ARCHS, reduced
    from repro.models import build
    from repro.serve.engine import PagedServeEngine, Request

    cfg = reduced(ALL_ARCHS["deepseek-7b"])
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = PagedServeEngine(model, params, slots=1, max_len=64,
                           block_size=4, num_blocks=2, chunk=4)
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(rid=0, prompt=list(range(30)), max_new=10))
    # a feasible request still serves on the same engine
    [ok] = eng.run([Request(rid=1, prompt=[1, 2, 3], max_new=4)])
    assert len(ok.out) == 4
