"""Tests for the paper-core layer: manifest, inspector (including the §8
diagnostic-tool claim: seeded misconfigurations must be detected), verify,
bootstrap, diagnostics."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, SHAPES
from repro.configs.base import TrainConfig
from repro.core import (Diagnostics, DualEnvHarness, Manifest, PortableEnv,
                        WireUp, constant_vs_scaling_overhead, diff,
                        init_benchmark, parse_hlo)
from repro.core.inspector import hlo_cost
from repro.launch.mesh import mesh_of


# ------------------------------------------------------------ manifest


def test_manifest_roundtrip_and_hash_stability():
    env = PortableEnv.capture(ALL_ARCHS["phi3-mini-3.8b"], SHAPES["train_4k"])
    m = Manifest(env)
    m2 = Manifest.from_json(m.to_json())
    assert m2.portable.image_hash == env.image_hash
    # identical capture -> identical hash (the image is content-addressed)
    env2 = PortableEnv.capture(ALL_ARCHS["phi3-mini-3.8b"], SHAPES["train_4k"])
    assert env2.image_hash == env.image_hash


def test_manifest_diff_classifies_portable_vs_host():
    a = Manifest(PortableEnv.capture(ALL_ARCHS["deepseek-7b"], SHAPES["train_4k"]))
    b = Manifest(PortableEnv.capture(ALL_ARCHS["deepseek-7b"], SHAPES["decode_32k"]))
    lines = diff(a, b)
    assert any("portable.shape" in line for line in lines)

    mesh = mesh_of((1, 1), ("data", "model"))
    a.bind(mesh)
    b2 = Manifest.from_json(a.to_json())
    assert diff(a, b2) == []


def test_manifest_attestation_detects_program_change():
    env = PortableEnv.capture(ALL_ARCHS["deepseek-7b"], SHAPES["train_4k"])
    a = Manifest(env).attest(hlo_text="HloModule A ...")
    b = Manifest(env).attest(hlo_text="HloModule B ...")
    lines = diff(a, b)
    assert any("hlo_fingerprint" in line and "UNEXPECTED" in line
               for line in lines)


# ------------------------------------------------------------ inspector


def _lower_hlo(fn, *args, n_dev=8):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_inspector_finds_collectives_in_real_module():
    """Compile a genuinely sharded program on a tiny in-process mesh and
    check the inspector sees its collectives."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import sys; sys.path.insert(0, "src")
        from repro.core.inspector import parse_hlo
        from repro.launch.mesh import mesh_of
        mesh = mesh_of((8,), ("d",))
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        f = lambda x, w: (x @ w).sum()
        lowered = jax.jit(f, in_shardings=(NamedSharding(mesh, P("d", None)),
                                           NamedSharding(mesh, P(None, "d")))
                          ).lower(x, w)
        hlo = lowered.compile().as_text()
        rep = parse_hlo(hlo, 8)
        kinds = set(op.kind for op in rep.ops)
        assert len(rep.ops) >= 1, hlo[:500]
        print("KINDS", sorted(kinds))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "KINDS" in out.stdout


def test_inspector_flags_monolithic_all_reduce():
    """§8 claim: a seeded pathway misconfiguration must be detected."""
    hlo = """HloModule bad

ENTRY %main (a: f32[268435456]) -> f32[268435456] {
  %a = f32[268435456] parameter(0)
  ROOT %ar = f32[268435456] all-reduce(%a), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, to_apply=%add
}
"""
    rep = parse_hlo(hlo, 16)
    assert any(f["kind"] == "monolithic-all-reduce" for f in rep.findings)


def test_inspector_flags_host_transfer():
    hlo = """HloModule ht
ENTRY %main () -> f32[1] {
  %tok = token[] after-all()
  %o = token[] outfeed(%c, %tok)
}
"""
    rep = parse_hlo(hlo, 1)
    assert any(f["kind"] == "host-transfer" for f in rep.findings)


def test_hlo_cost_counts_dot_flops():
    hlo = _lower_hlo(lambda a, b: a @ b,
                     jax.ShapeDtypeStruct((128, 256), jnp.float32),
                     jax.ShapeDtypeStruct((256, 64), jnp.float32))
    cost = hlo_cost(hlo)
    expect = 2 * 128 * 256 * 64
    assert abs(cost["dot_flops"] - expect) / expect < 1e-6


def test_hlo_cost_scan_trips():
    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    hlo = _lower_hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                     jax.ShapeDtypeStruct((12, 64, 64), jnp.float32))
    cost = hlo_cost(hlo)
    expect = 12 * 2 * 64**3
    assert abs(cost["dot_flops"] - expect) / expect < 0.01


# ------------------------------------------------------------- verify


def test_dual_env_agreement_and_divergence():
    h = DualEnvHarness(repeats=2, warmup=0)
    x = np.linspace(0, 1, 64)
    rep = h.compare("native", lambda: np.sin(x),
                    "container", lambda: np.sin(x) + 1e-9)
    assert rep.ok

    rep_bad = h.compare("native", lambda: np.sin(x),
                        "container", lambda: np.sin(x) * 1.5)
    assert not rep_bad.ok


def test_overhead_classification():
    # the paper's GPU-Arbor case: constant 17% at all scales
    assert constant_vs_scaling_overhead({1: 0.17, 8: 0.166, 64: 0.17}) \
        == "constant-overhead"
    # a communication penalty grows with scale
    assert constant_vs_scaling_overhead({1: 0.05, 8: 0.2, 64: 0.8}) \
        == "scaling-overhead"
    assert constant_vs_scaling_overhead({1: 0.001, 64: 0.01}) == "negligible"


# ------------------------------------------------------------ bootstrap


def test_wireup_from_slurm_env(monkeypatch):
    monkeypatch.setenv("SLURM_NTASKS", "128")
    monkeypatch.setenv("SLURM_PROCID", "7")
    monkeypatch.setenv("SLURM_STEP_NODELIST", "nid[001-032]")
    w = WireUp.from_env()
    assert w.num_processes == 128 and w.process_id == 7
    assert w.coordinator.startswith("nid")
    assert w.is_distributed


def test_init_benchmark_single_device():
    out = init_benchmark((1, 1), ("data", "model"), repeats=1)
    assert out["mesh_construct_s"] >= 0
    assert out["first_collective_s"] > 0


# ---------------------------------------------------------- diagnostics


def test_diagnostics_gate():
    d = Diagnostics()
    d.extend([{"severity": "info", "kind": "x", "detail": ""}], "t")
    assert d.gate()
    d.extend([{"severity": "error", "kind": "y", "detail": ""}], "t")
    assert not d.gate()
    assert d.worst == "error"
    assert "error" in d.render()
